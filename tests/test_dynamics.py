"""TopologyProgram layer: time-varying graphs as the third round axis.

Single-host: registry/spec round trips, per-round Assumption 1 over every
registered program, engine gating, and the DENSE PER-ROUND-W ORACLE --
every dynamic engine (flat, fused x {jnp, pallas} x {sequential,
pipelined}) must match a hand-written round loop that rebuilds W_r from
``program.weights_np`` each round (the eager twin of the traced gate) --
plus the zero-recompile property (one jit cache entry across rounds).

Multi-device (subprocess, 8 forced host devices, slow): sharded == fused
under churn for every program x schedule x wire encoding, the jaxpr
proof that churn adds ZERO collectives and ZERO extra compilations
relative to the static engine, the bitmap compact wire's collective
operand bytes == flat_wire_bytes, and a mid-churn pipelined checkpoint
restore that replays bit-identically.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
    pack,
    parse_program,
    program_names,
    resolve_program,
)
from repro.core.dynamics import STATIC, validate_program
from repro.core.packing import (
    bitmap_bytes_per_chunk,
    compact_index_bytes,
    flat_wire_bytes,
    pack_like,
    unpack,
)
from repro.core.schedules import constant
from repro.core.topology import check_assumption1
from repro.kernels.gossip.ref import fused_round_gt_ref, fused_round_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: one spec per registered dynamic program, sized for a 20-node graph
DYNAMIC_SPECS = (
    "edge_failure:p=0.3,seed=3",
    "node_churn:mean_downtime=3,p_down=0.25,seed=1",
    "round_robin_subgraphs:n_groups=3",
    "rgg_rewire:jitter=0.15,radius=0,seed=5",
)


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    return loss, params, batches


# ---------------------------------------------------------------------------
# registry + spec round trips
# ---------------------------------------------------------------------------


def test_program_registry_and_specs():
    assert program_names() == (
        "edge_failure", "node_churn", "rgg_rewire", "round_robin_subgraphs",
        "static",
    )
    assert resolve_program(None).is_static
    assert resolve_program("static").is_static
    prog = parse_program("edge_failure:p=0.35,seed=9")
    assert prog.p == 0.35 and prog.seed == 9
    assert resolve_program(prog) is prog
    # canonical spec round trip for every registered program
    for spec in ("static",) + DYNAMIC_SPECS:
        p = parse_program(spec)
        assert parse_program(p.spec()).spec() == p.spec()
    with pytest.raises(ValueError, match="unknown topology program"):
        parse_program("does_not_exist:p=1")
    with pytest.raises(ValueError, match="bad program knob"):
        parse_program("edge_failure:p")
    with pytest.raises(ValueError, match="bad knobs"):
        parse_program("edge_failure:nope=3")
    with pytest.raises(ValueError, match="p=1.5"):
        parse_program("edge_failure:p=1.5")
    # float knobs survive the manifest round trip at FULL precision --
    # a truncated spec would pass the restore-time equality check while
    # silently flipping edges near the lost digits
    hp = parse_program("edge_failure:p=0.1234567891,seed=0")
    assert parse_program(hp.spec()).p == hp.p == 0.1234567891


def test_program_bind_contract():
    w = mixing_matrix("ring", 8)
    prog = parse_program("edge_failure:p=0.2,seed=0")
    with pytest.raises(ValueError, match="unbound"):
        prog.weights_np(0)
    prog.bind(w)
    prog.bind(w)  # idempotent
    with pytest.raises(ValueError, match="already bound"):
        prog.bind(mixing_matrix("ring", 4))


# ---------------------------------------------------------------------------
# Assumption 1 on every registered program's emitted rounds (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ("static",) + DYNAMIC_SPECS)
def test_every_program_round_satisfies_assumption1(spec):
    """Symmetry + double stochasticity must hold EVERY round (a churn
    round may disconnect -- the gap check is relaxed, never the
    stochasticity); the active support must stay within the base; the
    diagonal absorbs exactly the dropped weight."""
    w = mixing_matrix("hospital20", 20)
    prog = parse_program(spec).bind(w)  # bind itself validates a sample
    base_off = np.abs(w - np.diag(np.diag(w))) > 0
    varied = False
    for r in range(10):
        w_r = prog.weights_np(r)
        diag = check_assumption1(w_r, atol=1e-6, require_connected=False)
        assert diag["sym_err"] <= 1e-6
        off_r = w_r - np.diag(np.diag(w_r))
        assert not (np.abs(off_r) > 0)[~base_off].any()
        # dropped weight folded into the self-loops, row by row
        np.testing.assert_allclose(
            np.diag(w_r), 1.0 - off_r.sum(axis=1), atol=1e-6
        )
        varied = varied or not np.allclose(w_r, w)
    assert varied == (spec != "static")
    validate_program(prog, w, rounds=10)


def test_node_churn_isolates_whole_nodes():
    w = mixing_matrix("hospital20", 20)
    prog = parse_program("node_churn:p_down=0.4,mean_downtime=2,seed=2")
    prog.bind(w)
    seen_isolated = False
    for r in range(8):
        w_r = prog.weights_np(r)
        off = w_r - np.diag(np.diag(w_r))
        row_deg = (np.abs(off) > 0).sum(axis=1)
        isolated = row_deg == 0
        seen_isolated = seen_isolated or isolated.any()
        # a down node is fully down: self-loop weight exactly 1
        np.testing.assert_allclose(np.diag(w_r)[isolated], 1.0)
        # persistence: rounds in the same block share the outage pattern
        w_same_block = prog.weights_np((r // 3) * 3)
    assert seen_isolated


def test_node_churn_correlated_recovery():
    """switch_groups: down nodes behind one failed switch share a single
    recovery coin, so a whole rack comes back in the same round -- and
    the grouped chain still replays bit-exactly from a checkpointed
    state via the stateless gate."""
    w = mixing_matrix("hospital20", 20)
    n, groups = 20, 4
    prog = parse_program(
        f"node_churn:p_down=0.5,mean_downtime=4,seed=3,"
        f"switch_groups={groups}").bind(w)
    assert prog.params()["switch_groups"] == groups
    # the default omits the knob, so pre-existing checkpoint specs
    # round-trip unchanged
    assert "switch_groups" not in parse_program("node_churn").params()

    key = jnp.asarray(prog.init_key())
    group = np.arange(n) * groups // n
    state = {k: jnp.asarray(v) for k, v in prog.init_state().items()}
    correlated = False
    for r in range(40):
        up = np.asarray(state["topo_up"])
        _, state = prog.gate_state(jnp.int32(r), key, state)
        new_up = np.asarray(state["topo_up"])
        recovered = (up < 0.5) & (new_up > 0.5)
        stayed = (up < 0.5) & (new_up < 0.5)
        for g in range(groups):
            m = group == g
            # one coin per rack: no rack splits into recovered + stayed
            assert not (recovered[m].any() and stayed[m].any()), r
            correlated = correlated or int(recovered[m].sum()) > 1
        if r in (5, 23, 39):
            # mid-outage replay: the stateless gate re-derives the same
            # chain state from round 0 (the checkpoint-restore oracle)
            np.testing.assert_array_equal(
                np.asarray(prog.gate(jnp.int32(r + 1), key)),
                np.outer(new_up, new_up))
    assert correlated


def test_round_robin_union_is_base_graph():
    w = mixing_matrix("hospital20", 20)
    g = 3
    prog = parse_program(f"round_robin_subgraphs:n_groups={g}").bind(w)
    base_off = np.abs(w - np.diag(np.diag(w))) > 0
    union = np.zeros_like(base_off)
    for r in range(g):
        w_r = prog.weights_np(r)
        union |= np.abs(w_r - np.diag(np.diag(w_r))) > 0
        # cycling: round r+g is identical
        np.testing.assert_array_equal(prog.weights_np(r + g), w_r)
    np.testing.assert_array_equal(union, base_off)


def test_gate_is_identical_eager_and_jit():
    """The graph sequence is a pure function of (seed, round): the
    counter-based hash must produce identical bits eagerly and under jit
    (the legacy threefry PRNG does NOT guarantee this once GSPMD
    partitions the program -- the reason jax.random is banned here)."""
    w = mixing_matrix("hospital20", 20)
    for spec in DYNAMIC_SPECS:
        prog = parse_program(spec).bind(w)
        key = jnp.asarray(prog.init_key())
        for r in (0, 3, 17):
            eager = prog.gate(jnp.int32(r), key)
            jitted = jax.jit(prog.gate)(jnp.int32(r), key)
            np.testing.assert_array_equal(np.asarray(eager),
                                          np.asarray(jitted))


# ---------------------------------------------------------------------------
# engine gating
# ---------------------------------------------------------------------------


def test_static_program_leaves_engines_unchanged():
    n, q = 8, 1
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    for name in ("tree", "flat"):
        eng, st0 = get_engine(name).simulated(
            w, params, topology_program="static"
        )
        assert not eng.dynamic_topology
        cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
        comm = eng.init_comm_state(cfg, st0)
        assert comm is None  # no topo counters on the static path
    eng, _ = get_engine("fused").simulated(
        w, params, scale_chunk=8, topology_program=None
    )
    assert eng.topology_program.is_static
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    assert "topo_round" not in eng.comm_keys(cfg)


def test_tree_engine_rejects_dynamic_program():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    with pytest.raises(ValueError, match="traced per-round"):
        get_engine("tree").simulated(
            w, params, topology_program="edge_failure:p=0.2"
        )


def test_dynamic_engine_comm_contract():
    n = 8
    w = mixing_matrix("ring", n)
    _, params, _ = _problem(n, 1)
    eng, flat0 = get_engine("fused").simulated(
        w, params, scale_chunk=8, impl="jnp",
        topology_program="edge_failure:p=0.2,seed=1",
    )
    cfg = FLConfig(algorithm="dsgt", q=1, n_nodes=n)
    keys = eng.comm_keys(cfg)
    assert "topo_round" in keys and "topo_key" in keys
    comm = eng.init_comm_state(cfg, flat0)
    assert comm["topo_round"].dtype == jnp.int32
    assert int(comm["topo_round"]) == 0
    np.testing.assert_array_equal(
        np.asarray(comm["topo_key"]),
        np.asarray(eng.topology_program.init_key()),
    )
    sds = eng.comm_state_sds(cfg)
    assert sds["topo_key"].shape == (2,) and sds["topo_key"].dtype == jnp.uint32


# ---------------------------------------------------------------------------
# the dense per-round-W oracle
# ---------------------------------------------------------------------------


def _oracle_rounds(loss, params, batches, prog, cfg, alpha, rounds, chunk,
                   engine_kind, pipelined=False):
    """Hand-written round loop against the PER-ROUND dense W rebuilt from
    ``program.weights_np`` -- exact-wire mix-then-adapt for the flat
    engine, the fused-round jnp references (stale_mix for pipelined) for
    the fused engines."""
    flat, layout = pack(params, pad_to=chunk)
    grad_fn = jax.vmap(jax.value_and_grad(loss))

    def eval_grads(fb, batch):
        losses, grads = grad_fn(unpack(fb, layout), batch)
        return losses, pack_like(grads, layout)

    q = cfg.q
    x = flat + 0.0
    zeros = jnp.zeros_like(x)
    tr, gp = zeros, zeros
    rx, sx, rt, st_ = zeros, zeros, zeros, zeros
    for r in range(rounds):
        for i in range(q - 1):
            _, g = eval_grads(x, jax.tree_util.tree_map(lambda b: b[i], batches))
            x = x - alpha * g
        _, g = eval_grads(x, jax.tree_util.tree_map(lambda b: b[q - 1], batches))
        w_r = prog.weights_np(r)
        w_off = jnp.asarray(w_r - np.diag(np.diag(w_r)), jnp.float32)
        w_self = jnp.asarray(np.diag(w_r), jnp.float32)
        if engine_kind == "flat":
            if cfg.algorithm == "dsgd":
                x = (w_off @ x + w_self[:, None] * x) - alpha * g
            else:
                tr = (w_off @ tr + w_self[:, None] * tr) + g - gp
                x = (w_off @ x + w_self[:, None] * x) - alpha * tr
                gp = g
        elif cfg.algorithm == "dsgd":
            x, rx, sx, _ = fused_round_ref(
                x, g, rx, sx, w_off, w_self, jnp.float32(alpha),
                scale_chunk=chunk, stale_mix=pipelined,
            )
        else:
            x, tr, rx, sx, rt, st_, _, _ = fused_round_gt_ref(
                x, tr, g, gp, rx, sx, rt, st_, w_off, w_self,
                jnp.float32(alpha), scale_chunk=chunk, stale_mix=pipelined,
            )
            gp = g
    return x


@pytest.mark.parametrize("spec", DYNAMIC_SPECS)
@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_flat_dynamic_matches_per_round_w_oracle(spec, algorithm):
    n, q, chunk, rounds = 8, 2, 8, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    eng, flat0 = get_engine("flat").simulated(
        w, params, scale_chunk=chunk, topology_program=spec
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)
    assert rf._cache_size() == 1  # churn adds ZERO recompiles
    assert 0.0 <= float(m["edge_fraction"]) <= 1.0
    oracle = _oracle_rounds(loss, params, batches, eng.topology_program,
                            cfg, 0.05, rounds, chunk, "flat")
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


@pytest.mark.parametrize("spec", DYNAMIC_SPECS)
@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
@pytest.mark.parametrize("schedule", ["sequential", "pipelined"])
def test_fused_dynamic_matches_per_round_w_oracle(spec, algorithm, schedule):
    n, q, chunk, rounds = 8, 2, 8, 4
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    eng, flat0 = get_engine("fused").simulated(
        w, params, scale_chunk=chunk, impl="pallas", topology_program=spec,
        round_schedule=schedule,
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, m = rf(st, batches)
    assert rf._cache_size() == 1  # churn adds ZERO recompiles
    assert int(st.comm["topo_round"]) == rounds
    oracle = _oracle_rounds(loss, params, batches, eng.topology_program,
                            cfg, 0.05, rounds, chunk, "fused",
                            pipelined=(schedule == "pipelined"))
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(oracle),
                               atol=1e-5)


def test_fused_dynamic_topk_still_matches_oracle():
    """top-k sparsification composes with churn (EF absorbs both)."""
    n, q, chunk, rounds, topk = 8, 1, 8, 4, 3
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    eng, flat0 = get_engine("fused").simulated(
        w, params, scale_chunk=chunk, impl="pallas", topk=topk,
        topology_program="edge_failure:p=0.4,seed=3",
    )
    rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
    st = init_fl_state(cfg, flat0, engine=eng)
    for _ in range(rounds):
        st, _ = rf(st, batches)
    prog = eng.topology_program
    flat, layout = pack(params, pad_to=chunk)
    grad_fn = jax.vmap(jax.value_and_grad(loss))
    x = flat + 0.0
    rx = jnp.zeros_like(x)
    sx = jnp.zeros_like(x)
    for r in range(rounds):
        _, grads = grad_fn(unpack(x, layout),
                           jax.tree_util.tree_map(lambda b: b[0], batches))
        g = pack_like(grads, layout)
        w_r = prog.weights_np(r)
        x, rx, sx, _ = fused_round_ref(
            x, g, rx, sx,
            jnp.asarray(w_r - np.diag(np.diag(w_r)), jnp.float32),
            jnp.asarray(np.diag(w_r), jnp.float32),
            jnp.float32(0.05), scale_chunk=chunk, topk=topk,
        )
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(x),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# bitmap compact-wire encoding (satellite)
# ---------------------------------------------------------------------------


def test_flat_wire_bytes_picks_cheaper_index_encoding():
    tree = {"a": jnp.zeros((4, 1000)), "b": jnp.zeros((4, 100))}
    _, layout = pack(tree, pad_to=512)
    n_chunks = layout.total // 512
    # k=64 on 512-wide chunks: bitmap (64 B) beats int16 positions (128 B)
    assert compact_index_bytes(512, 64) == 64
    assert flat_wire_bytes(layout, 1, 512, 64) == n_chunks * (64 + 64 + 4)
    # the modeled 3.9x at k=64/512 is REALIZED by the bitmap encoding
    dense = flat_wire_bytes(layout, 1, 512)
    assert dense / flat_wire_bytes(layout, 1, 512, 64) == pytest.approx(
        3.9, abs=0.05
    )
    # tiny k on wide chunks: explicit positions win
    assert compact_index_bytes(512, 8) == 16
    assert flat_wire_bytes(layout, 1, 512, 8) == n_chunks * (8 + 16 + 4)
    # non-byte-aligned chunks have no bitmap
    assert bitmap_bytes_per_chunk(12) is None
    assert compact_index_bytes(12, 6) == 12


def test_bitmap_round_trip_property():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.kernels.gossip.ref import (
        _quantize_ef_compact_chunks,
        compact_to_bitmap,
        scatter_bitmap_dq,
        scatter_compact_dq,
    )
    from repro.core.packing import compact_pos_dtype

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        k=st.sampled_from([1, 3, 8, 15]),
        structure=st.sampled_from(["normal", "ties", "sparse", "zeros"]),
    )
    def check(seed, k, structure):
        n, chunk, c = 4, 16, 3
        t = c * chunk
        rng = np.random.default_rng(seed)
        if structure == "normal":
            payload = rng.normal(size=(n, t))
        elif structure == "ties":
            payload = rng.integers(-3, 4, size=(n, t)).astype(np.float64)
        elif structure == "sparse":
            payload = rng.normal(size=(n, t)) * (rng.random((n, t)) < 0.1)
        else:
            payload = np.zeros((n, t))
        payload = jnp.asarray(payload, jnp.float32)
        q, pos, scales, dq = _quantize_ef_compact_chunks(payload, chunk, k)
        q8 = q.astype(jnp.int8)
        p16 = pos.astype(compact_pos_dtype(chunk))
        vals, bits = compact_to_bitmap(q8, p16, chunk, k)
        assert vals.dtype == jnp.int8 and bits.dtype == jnp.uint8
        assert bits.shape == (n, c * chunk // 8)
        rebuilt = scatter_bitmap_dq(vals, bits, scales, chunk, t)
        # bitmap decode == positions decode == the sender's dense dq
        np.testing.assert_array_equal(
            np.asarray(rebuilt),
            np.asarray(scatter_compact_dq(q8, p16, scales, chunk, t)),
        )
        np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(dq))

    check()


def test_bitmap_in_kernel_epilogue_bit_identical_dsgd():
    """``bitmap=True`` folds the re-encode (position argsort + bit-pack)
    INTO the wire-stage tile: its (vals, bits) output must be
    bit-identical to the explicit-positions kernel followed by the jnp
    re-encode, every other output untouched, and the receive-side bitmap
    decode must rebuild the exact explicit-positions payload."""
    from repro.kernels.gossip import ops
    from repro.kernels.gossip.ref import compact_to_bitmap, scatter_bitmap_dq, \
        scatter_compact_dq

    n, total, chunk, k = 4, 512, 64, 16
    rng = np.random.default_rng(0)
    mk = lambda s=1.0: jnp.asarray(rng.normal(size=(n, total)) * s,
                                   jnp.float32)
    x, g, res = mk(), mk(), mk(0.1)
    recon = jnp.zeros((n, total), jnp.float32)
    alpha = jnp.float32(0.05)
    kw = dict(scale_chunk=chunk, topk=k)

    a = ops.wire_stage_compact(x, g, recon, res, alpha, bitmap=True, **kw)
    b = ops.wire_stage_compact(x, g, recon, res, alpha, **kw)
    vals, bits = compact_to_bitmap(b[1], b[2], chunk, k)
    assert a[1].dtype == jnp.int8 and a[2].dtype == jnp.uint8
    assert a[2].shape == (n, total // 8)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(vals))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(bits))
    for i in (0, 3, 4, 5):  # h, scales, new_recon, new_res: untouched
        np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b[i]))
    np.testing.assert_array_equal(
        np.asarray(scatter_bitmap_dq(a[1], a[2], a[3], chunk, total)),
        np.asarray(scatter_compact_dq(b[1], b[2], b[3], chunk, total)))


def test_bitmap_in_kernel_epilogue_bit_identical_gt():
    """The gradient-tracking twin: one pallas pass packs BOTH wires."""
    from repro.kernels.gossip import ops
    from repro.kernels.gossip.ref import compact_to_bitmap

    n, total, chunk, k = 4, 512, 64, 16
    rng = np.random.default_rng(1)
    mk = lambda s=1.0: jnp.asarray(rng.normal(size=(n, total)) * s,
                                   jnp.float32)
    x, t, g, gp = mk(), mk(), mk(), mk()
    sx, st_ = mk(0.1), mk(0.1)
    rx = jnp.zeros((n, total), jnp.float32)
    rt = jnp.zeros((n, total), jnp.float32)
    alpha = jnp.float32(0.05)
    kw = dict(scale_chunk=chunk, topk=k)

    A = ops.wire_stage_gt_compact(x, t, g, gp, rx, sx, rt, st_, alpha,
                                  bitmap=True, **kw)
    B = ops.wire_stage_gt_compact(x, t, g, gp, rx, sx, rt, st_, alpha, **kw)
    vx, bx = compact_to_bitmap(B[2], B[3], chunk, k)
    vt, bt = compact_to_bitmap(B[7], B[8], chunk, k)
    for got, want in ((A[2], vx), (A[3], bx), (A[7], vt), (A[8], bt)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for i in (0, 1, 4, 5, 6, 9, 10, 11):  # everything but the wires
        np.testing.assert_array_equal(np.asarray(A[i]), np.asarray(B[i]))


# ---------------------------------------------------------------------------
# sharded: churn == fused oracle, zero extra collectives / compiles,
# bitmap operand bytes, mid-churn pipelined restore (subprocess, 8 devices)
# ---------------------------------------------------------------------------


def _run(script: str, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (FLConfig, FusedEngine, ShardedFusedEngine,
                            flat_wire_bytes, init_fl_state, make_fl_round,
                            mixing_matrix, pack)
    from repro.core.schedules import inv_sqrt
    from repro.launch.mesh import make_test_mesh, node_axes, n_fl_nodes

    mesh = make_test_mesh((2, 2, 2))
    naxes = node_axes(mesh); n = n_fl_nodes(mesh)
    rng = np.random.default_rng(0)
    q, chunk = 2, 16
    SPECS = ("edge_failure:p=0.4,seed=3",
             "node_churn:mean_downtime=2,p_down=0.3,seed=1",
             "round_robin_subgraphs:n_groups=2",
             "rgg_rewire:jitter=0.2,radius=0,seed=5")

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.asarray(rng.normal(size=(n, 4, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 5)), jnp.float32)}
    flat, layout = pack(params, pad_to=chunk)
    sched = inv_sqrt(0.05)
    put = lambda: jax.device_put(flat, NamedSharding(mesh, P(naxes, None)))

    # 1. sharded churn == fused churn (the single-host oracle, itself
    #    proven against the per-round-W reference in test_dynamics.py)
    #    over program x algorithm x schedule x {dense int8, compact}
    def compare(algorithm, topk, schedule, spec, w=None, rounds=4):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        sh = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", round_schedule=schedule, topology_program=spec,
            w=w)
        fe = FusedEngine(sh.dense_equivalent(), layout, scale_chunk=chunk,
                         topk=topk, impl="pallas", round_schedule=schedule,
                         topology_program=spec)
        rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=fe))
        st_f = init_fl_state(cfg, flat, engine=fe)
        with mesh:
            rf_s = jax.jit(make_fl_round(loss, None, sched, cfg, engine=sh))
            st_s = init_fl_state(cfg, put(), engine=sh)
            for _ in range(rounds):
                st_f, m_f = rf_f(st_f, batches)
                st_s, m_s = rf_s(st_s, batches)
        if w is not None:
            # the dense-W dynamic round tracks ALL nodes' reconstructions
            assert "nbr_recon_all" in st_s.comm, (schedule, spec)
        err = float(jnp.abs(st_f.params - st_s.params).max())
        assert err < 1e-5, (algorithm, topk, schedule, spec, err)
        if algorithm == "dsgt":
            terr = float(jnp.abs(st_f.tracker - st_s.tracker).max())
            assert terr < 1e-5, (algorithm, topk, schedule, spec, terr)
        assert float(m_f["edge_fraction"]) == float(m_s["edge_fraction"])
        assert float(m_f["wire_bytes"]) == float(m_s["wire_bytes"])
        # churn adds zero RECOMPILES: one cache entry beyond the
        # first-call sharding commitment, same as the static engine
        assert rf_s._cache_size() <= 2, rf_s._cache_size()

    for spec in SPECS:
        for algorithm in ("dsgd", "dsgt"):
            compare(algorithm, None, "sequential", spec)
    compare("dsgt", None, "pipelined", SPECS[0])
    compare("dsgd", None, "pipelined", SPECS[1])
    compare("dsgt", 4, "sequential", SPECS[1])   # compact bitmap wire
    compare("dsgd", 4, "pipelined", SPECS[0])

    # dense-W sharded dynamics: churn on the all-gather dense-W wire
    # (nbr_recon_all), across schedules up to depth-2 bounded staleness
    w_er = mixing_matrix("erdos_renyi", n, p=0.7, seed=1)
    compare("dsgd", None, "sequential", SPECS[0], w=w_er)
    compare("dsgt", None, "sequential", SPECS[1], w=w_er)
    compare("dsgt", 4, "pipelined", SPECS[0], w=w_er)
    compare("dsgd", None, "pipelined", SPECS[3], w=w_er)
    compare("dsgd", None, "bounded_staleness:k=2", SPECS[0], w=w_er,
            rounds=5)
    compare("dsgt", None, "bounded_staleness:k=2", SPECS[1], rounds=5)

    # 2. jaxpr: churn adds ZERO collectives (same ppermute count as the
    #    static engine; the gate only zeroes contributions) and the round
    #    is still ONE wire-stage kernel; the compact wire's collective
    #    operands are exactly the flat_wire_bytes BITMAP encoding
    def walk(jaxpr, name, found):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                found.append(eqn)
            for v in eqn.params.values():
                subs = v if isinstance(v, (list, tuple)) else [v]
                for sub in subs:
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr, name, found)
                    elif hasattr(sub, "eqns"):
                        walk(sub, name, found)
        return found

    def round_jaxpr(spec, topk, algorithm="dsgt"):
        cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
        eng = ShardedFusedEngine.from_mesh(
            mesh, naxes, params, scale_chunk=chunk, topk=topk,
            impl="pallas", topology_program=spec)
        with mesh:
            rf = make_fl_round(loss, None, sched, cfg, engine=eng)
            st = init_fl_state(cfg, put(), engine=eng)
            return eng, jax.make_jaxpr(rf)(st, batches)

    for topk in (None, 4):
        _, static_jx = round_jaxpr(None, topk)
        eng, churn_jx = round_jaxpr(SPECS[1], topk)
        n_static = len(walk(static_jx.jaxpr, "ppermute", []))
        n_churn = len(walk(churn_jx.jaxpr, "ppermute", []))
        assert n_churn == n_static, (topk, n_static, n_churn)
        assert len(walk(churn_jx.jaxpr, "pallas_call", [])) == 1
        if topk is not None:
            assert eng.wire_encoding == "bitmap"
            pp = walk(churn_jx.jaxpr, "ppermute", [])
            wires = 2
            dirs = n_static // (3 * wires)
            one_dir = pp[:3]
            moved = sum(int(np.prod(e.invars[0].aval.shape))
                        * e.invars[0].aval.dtype.itemsize for e in one_dir)
            assert moved == flat_wire_bytes(layout, 1, chunk, 4), moved

    # 2b. the bitmap re-encode is an IN-KERNEL epilogue on the pallas
    #     path: every sort in the round jaxpr lives INSIDE the single
    #     pallas_call (the epilogue's position argsort); nothing outside
    #     the kernel touches explicit positions
    def walk_outside(jaxpr, name, found):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                continue
            if eqn.primitive.name == name:
                found.append(eqn)
            for v in eqn.params.values():
                subs = v if isinstance(v, (list, tuple)) else [v]
                for sub in subs:
                    if hasattr(sub, "jaxpr"):
                        walk_outside(sub.jaxpr, name, found)
                    elif hasattr(sub, "eqns"):
                        walk_outside(sub, name, found)
        return found

    eng_b, jx_b = round_jaxpr(None, 4)
    assert eng_b.wire_encoding == "bitmap"
    outer = len(walk_outside(jx_b.jaxpr, "sort", []))
    total_sorts = len(walk(jx_b.jaxpr, "sort", []))
    assert outer == 0, f"{outer} post-kernel sorts: re-encode left the kernel"
    assert total_sorts >= 1, "epilogue argsort missing from the kernel"

    # 3. mid-churn PIPELINED checkpoint restore: counters + in-flight
    #    wire + per-direction accumulators all land consistently; the
    #    continued run replays the identical graph sequence
    import tempfile
    from repro.training.checkpoint import load_fl_state, save_fl_state
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    eng = ShardedFusedEngine.from_mesh(
        mesh, naxes, params, scale_chunk=chunk, topk=4, impl="pallas",
        round_schedule="pipelined", topology_program=SPECS[1])
    with mesh:
        rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng))
        st = init_fl_state(cfg, put(), engine=eng)
        for _ in range(3):
            st, _ = rf(st, batches)
        with tempfile.TemporaryDirectory() as d:
            save_fl_state(d, st, engine=eng)
            import json as _json
            manifest = _json.load(open(os.path.join(d, "manifest.json")))
            assert manifest["topology_program"] == SPECS[1]
            assert "topo_round" in manifest["comm_keys"]
            assert any(k.startswith("nbr_recon_")
                       for k in manifest["comm_keys"])
            back = load_fl_state(d, init_fl_state(cfg, put(), engine=eng),
                                 engine=eng)
        assert int(back.comm["topo_round"]) == 3
        for _ in range(3):
            st, _ = rf(st, batches)
            back, _ = rf(back, batches)
    err = float(jnp.abs(st.params - back.params).max())
    assert err < 1e-6, err

    # 4. a STATIC sharded checkpoint seeds a dynamic run: its derived
    #    mix_recon is dropped (is_derived_comm_key), the per-direction
    #    accumulators are rebuilt from recon, the program starts at
    #    round 0
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    st_eng = ShardedFusedEngine.from_mesh(
        mesh, naxes, params, scale_chunk=chunk)
    with mesh:
        rf = jax.jit(make_fl_round(loss, None, sched, cfg, engine=st_eng))
        st = init_fl_state(cfg, put(), engine=st_eng)
        for _ in range(2):
            st, _ = rf(st, batches)
        with tempfile.TemporaryDirectory() as d:
            save_fl_state(d, st, engine=st_eng)
            dyn = ShardedFusedEngine.from_mesh(
                mesh, naxes, params, scale_chunk=chunk,
                topology_program=SPECS[0])
            back = load_fl_state(
                d, init_fl_state(cfg, put(), engine=dyn), engine=dyn)
        assert "mix_recon" not in back.comm
        assert int(back.comm["topo_round"]) == 0
        for d_i, src in enumerate(dyn._dir_src):
            np.testing.assert_allclose(
                np.asarray(back.comm[f"nbr_recon_{d_i}"]),
                np.asarray(back.comm["recon"])[src])
        rf2 = jax.jit(make_fl_round(loss, None, sched, cfg, engine=dyn))
        back, _ = rf2(back, batches)
    print("DYNAMICS-SHARDED-OK")
    """
)


@pytest.mark.slow
def test_sharded_dynamics_matrix():
    out = _run(_SHARDED_SCRIPT)
    assert "DYNAMICS-SHARDED-OK" in out
