"""The GossipEngine protocol layer: registry resolution, legacy-kwarg
migration errors, engine-built rounds matching each other, wire-byte
accounting, and checkpoint round-trips of the new engine comm state.
(The hypothesis property tests for top-k + EF consensus contraction live
in tests/test_topk_property.py so this module runs without hypothesis.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    FlatEngine,
    FusedEngine,
    ShardedFusedEngine,
    TreeEngine,
    engine_names,
    get_engine,
)
from repro.core.fl import FLConfig, init_fl_state, make_fl_round
from repro.core.mixing import make_dense_flat_mix, make_dense_gossip
from repro.core.packing import flat_wire_bytes, pack, pack_layout
from repro.core.schedules import constant
from repro.core.topology import mixing_matrix


def _problem(n, q, seed=0):
    rng = np.random.default_rng(seed)

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) + jnp.sum(p["b"] ** 2)

    params = {
        "w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
    }
    batches = {"t": jnp.asarray(rng.normal(size=(q, n, 4, 3)), jnp.float32)}
    return loss, params, batches


# ---------------------------------------------------------------------------
# registry + migration
# ---------------------------------------------------------------------------


def test_registry_has_all_engines():
    assert engine_names() == ("flat", "fused", "sharded_fused", "tree")
    assert get_engine("tree") is TreeEngine
    assert get_engine("flat") is FlatEngine
    assert get_engine("fused") is FusedEngine
    assert get_engine("sharded_fused") is ShardedFusedEngine


def test_unknown_engine_lists_registry():
    with pytest.raises(ValueError, match="sharded_fused"):
        get_engine("does-not-exist")


def test_legacy_kwargs_raise_with_migration_hint():
    n = 4
    loss, params, _ = _problem(n, 1)
    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=n)
    flat, layout = pack(params, pad_to=8)
    for legacy in ({"layout": layout}, {"fused": object()},
                   {"layout": layout, "fused": object()}):
        with pytest.raises(TypeError, match="GossipEngine"):
            make_fl_round(loss, None, constant(0.1), cfg, **legacy)
    with pytest.raises(TypeError, match="GossipEngine"):
        init_fl_state(cfg, flat, fused=True)
    # engine + gossip_fn is ambiguous
    with pytest.raises(ValueError, match="inside the engine"):
        make_fl_round(loss, lambda t: t, constant(0.1), cfg,
                      engine=FlatEngine(lambda f: f, layout))
    # neither is an error too
    with pytest.raises(ValueError, match="gossip_fn or an"):
        make_fl_round(loss, None, constant(0.1), cfg)


def test_sharded_fused_rejects_simulated_build():
    w = mixing_matrix("ring", 4)
    _, params, _ = _problem(4, 1)
    with pytest.raises(ValueError, match="mesh"):
        get_engine("sharded_fused").simulated(w, params)


# ---------------------------------------------------------------------------
# engine-built rounds agree across representations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["dsgd", "dsgt"])
def test_tree_and_flat_engines_match(algorithm):
    n, q = 8, 2
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=3)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = constant(0.05)

    eng_t, p_t = get_engine("tree").simulated(w, params)
    eng_f, p_f = get_engine("flat").simulated(w, params, scale_chunk=8)
    rf_t = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng_t))
    rf_f = jax.jit(make_fl_round(loss, None, sched, cfg, engine=eng_f))
    st_t = init_fl_state(cfg, p_t, engine=eng_t)
    st_f = init_fl_state(cfg, p_f, engine=eng_f)
    for _ in range(3):
        st_t, _ = rf_t(st_t, batches)
        st_f, _ = rf_f(st_f, batches)
    back = eng_f.params_view(st_f.params)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(back[k]), np.asarray(st_t.params[k]), atol=1e-5
        )


def test_gossip_fn_positional_is_tree_engine_sugar():
    n, q = 4, 1
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=5)
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    rf_sugar = jax.jit(make_fl_round(loss, make_dense_gossip(w), constant(0.1), cfg))
    rf_eng = jax.jit(make_fl_round(
        loss, None, constant(0.1), cfg, engine=TreeEngine(make_dense_gossip(w))
    ))
    st = init_fl_state(cfg, params)
    (s1, m1), (s2, m2) = rf_sugar(st, batches), rf_eng(st, batches)
    for k in params:
        np.testing.assert_array_equal(np.asarray(s1.params[k]), np.asarray(s2.params[k]))


# ---------------------------------------------------------------------------
# top-k wire accounting
# ---------------------------------------------------------------------------


def test_topk_wire_bytes_below_int8():
    _, params, _ = _problem(16, 1)
    flat, layout = pack(params, pad_to=8)
    dense = flat_wire_bytes(layout, 3, 8)
    sparse = flat_wire_bytes(layout, 3, 8, topk=2)
    assert sparse < dense
    # the REALIZED compact encoding: 2 int8 values + the CHEAPER index
    # encoding (here the presence bitmap: 8/8 = 1 B beats 2 int16
    # positions = 4 B) + 4 B scale per chunk -- what the engine's
    # collective operands actually are (asserted against the jaxpr in
    # tests/test_schedule.py and tests/test_dynamics.py)
    n_chunks = layout.total // 8
    assert sparse == 3 * n_chunks * (2 + 1 + 4)
    # explicit positions win only for tiny k on wide chunks (k < chunk/16)
    _, wide = pack(params, pad_to=64)
    n_wide = wide.total // 64
    assert flat_wire_bytes(wide, 1, 64, topk=2) == n_wide * (2 + 2 * 2 + 4)
    assert flat_wire_bytes(wide, 1, 64, topk=8) == n_wide * (8 + 8 + 4)
    # degenerate k >= chunk falls back to dense accounting
    assert flat_wire_bytes(layout, 3, 8, topk=8) == dense
    # the cap: a compact encoding that would exceed dense ships dense
    assert flat_wire_bytes(layout, 1, 8, topk=7) == flat_wire_bytes(layout, 1, 8)


def test_fused_engine_wire_bytes_metric_drops_with_topk():
    n, q, chunk = 8, 1, 32
    w = mixing_matrix("ring", n)
    loss, params, batches = _problem(n, q, seed=2)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    metrics = {}
    for tk in (None, 4):
        eng, flat = get_engine("fused").simulated(
            w, params, scale_chunk=chunk, topk=tk, impl="jnp"
        )
        rf = jax.jit(make_fl_round(loss, None, constant(0.05), cfg, engine=eng))
        st = init_fl_state(cfg, flat, engine=eng)
        _, m = rf(st, batches)
        metrics[tk] = float(m["wire_bytes"])
        assert metrics[tk] == eng.wire_bytes(cfg)
    assert metrics[4] < metrics[None]


# ---------------------------------------------------------------------------
# checkpoint round-trip of the new engine comm state
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_engine_comm_state(tmp_path):
    """Every comm buffer an engine declares survives save/load, the
    manifest records the engine name, and a checkpoint from an engine
    with FEWER comm buffers restores onto a richer template with the
    extra buffers left zero-initialized (the sharded engine's mix_recon
    accumulators)."""
    from repro.training.checkpoint import load_fl_state, save_fl_state

    cfg = FLConfig(algorithm="dsgt", q=2, n_nodes=4)
    w = mixing_matrix("ring", 4)
    flat = jnp.arange(4 * 32, dtype=jnp.float32).reshape(4, 32)
    layout = pack_layout(flat)
    fused = FusedEngine(w, layout, scale_chunk=16)

    st = init_fl_state(cfg, flat, engine=fused)
    assert set(st.comm) == {"recon", "residual", "recon_t", "residual_t"}
    st = st._replace(comm={k: v + i for i, (k, v) in enumerate(st.comm.items(), 1)})
    path = str(tmp_path / "fused")
    save_fl_state(path, st, engine=fused)

    import json, os
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    assert manifest["engine"] == "fused"
    assert manifest["comm_keys"] == sorted(st.comm)

    back = load_fl_state(path, init_fl_state(cfg, flat, engine=fused), engine=fused)
    for k in st.comm:
        np.testing.assert_array_equal(np.asarray(back.comm[k]), np.asarray(st.comm[k]))

    # restoring onto the sharded template: shared buffers restored, and the
    # DERIVED mix_recon accumulators are rebuilt by the engine's
    # restore_comm hook (mix_recon == W_off @ recon -- the sharded
    # invariant; a zero template value would silently break mixing)
    sharded_keys = ("recon", "residual", "mix_recon",
                    "recon_t", "residual_t", "mix_recon_t")
    template = st._replace(
        comm={k: jnp.zeros_like(flat) for k in sharded_keys}
    )
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)

    class _FakeSharded:
        name = "sharded_fused"

        def restore_comm(self, comm):
            comm = dict(comm)
            comm["mix_recon"] = w_off @ comm["recon"]
            comm["mix_recon_t"] = w_off @ comm["recon_t"]
            return comm

    back2 = load_fl_state(path, template, engine=_FakeSharded())
    for k in st.comm:
        np.testing.assert_array_equal(np.asarray(back2.comm[k]), np.asarray(st.comm[k]))
    np.testing.assert_allclose(
        np.asarray(back2.comm["mix_recon"]),
        np.asarray(w_off @ st.comm["recon"]), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(back2.comm["mix_recon_t"]),
        np.asarray(w_off @ st.comm["recon_t"]), atol=1e-6)
    # without engine= the partial restore refuses (derived state cannot be
    # rebuilt blindly)
    with pytest.raises(ValueError, match="rebuilt"):
        load_fl_state(path, template)

    # the reverse direction (richer checkpoint onto a poorer template)
    # must refuse rather than silently drop wire state
    st_sh = template._replace(
        comm={k: v + 1.0 for k, v in template.comm.items()}
    )
    path2 = str(tmp_path / "sharded")
    save_fl_state(path2, st_sh, engine=_FakeSharded())
    with pytest.raises(ValueError, match="mix_recon"):
        load_fl_state(path2, init_fl_state(cfg, flat, engine=fused), engine=fused)


def test_checkpoint_rejects_unregistered_engine(tmp_path):
    from repro.training.checkpoint import load_fl_state, save_fl_state

    cfg = FLConfig(algorithm="dsgd", q=1, n_nodes=4)
    flat = jnp.ones((4, 8), jnp.float32)
    st = init_fl_state(cfg, flat)
    path = str(tmp_path)
    save_fl_state(path, st)
    import json, os
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["engine"] = "renamed-away"
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ValueError, match="registry"):
        load_fl_state(path, st)
