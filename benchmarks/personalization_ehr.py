"""Personalization-vs-consensus frontier -> experiments/personalization_ehr.json.

The sixth round axis (``repro.core.scope``) makes gossip PARTIAL: under
``--fl-scope backbone`` the hospitals share every column except the
classifier head, each head training purely on local gradients,
bit-untouched by the wire. This benchmark quantifies when that wins.

It runs on the HARDENED cohort (``generate_ehr_cohort`` with
``label_shift`` / ``minority_concentration`` / ``conditional_shift``):
per-hospital AD prevalence spreads from <1% to ~90% and the AD cluster's
mean drifts along a hospital-specific direction, so the Bayes-optimal
classifier genuinely differs per hospital -- the regime arxiv 2209.08737
shows favors a shared backbone + private heads over full consensus.

Cells (equal round budget, FD-DSGT, fused engine, hospital graph):

* ``full``      -- the paper's full-consensus gossip; every hospital
                   deploys (approximately) the same consensus model.
* ``backbone``  -- shared backbone, private per-hospital heads; each
                   hospital deploys consensus-backbone + OWN head.
* ``layerwise`` -- the head joins the mix every 4th round (same wire
                   width as full; a consensus/personalization midpoint).

Headline: mean per-hospital balanced accuracy (each hospital's deployed
model on its own patients). Acceptance (asserted in-script, non-smoke):
``backbone`` >= ``full`` with STRICTLY fewer wire bytes per round.

The wire-byte columns are the ones ``tools/bench_guard.py`` gates, and
the scoped wire obeys an EXACT linearity identity asserted here:
``flat_wire_bytes`` is linear in the layout total, so

    wire_scoped * total_full == wire_full * total_scoped

to the byte (the shared-fraction x full-wire identity). ``layerwise``
must ship the FULL wire (the round-gate changes what the mix keeps, not
what the collective moves -- CHOCO reconstructions track the sender).

Usage: PYTHONPATH=src python benchmarks/personalization_ehr.py \
           [--rounds 80] [--q 10] [--out experiments/personalization_ehr.json]
       PYTHONPATH=src python benchmarks/personalization_ehr.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ehr_mlp import class_weights
from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.data.partition import cohort_label_stats
from repro.models.mlp import make_mlp_loss, mlp_balanced_accuracy, mlp_init
from repro.training.trainer import stack_for_nodes

#: the hardened-cohort knobs (see repro.data.ehr.generate_ehr_cohort):
#: prevalence tilt, minority concentration, class-conditional drift
LABEL_SHIFT = 1.5
MINORITY_CONCENTRATION = 1.0
CONDITIONAL_SHIFT = 4.0


def _hard_cohort(seed: int):
    return generate_ehr_cohort(
        seed=seed,
        label_shift=LABEL_SHIFT,
        minority_concentration=MINORITY_CONCENTRATION,
        conditional_shift=CONDITIONAL_SHIFT,
    )


def run_cell(name: str, scope, rounds: int, q: int, seed: int = 0,
             alpha0: float = 0.01) -> dict:
    """One scope cell: FD-DSGT, fused engine, hardened hospital cohort,
    equal round budget everywhere."""
    n = 20
    data = _hard_cohort(seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)
    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    # chunk 128 (not the 512 default): the backbone slice is 1376 of
    # 1536 columns on this MLP, and the scoped wire pads to a chunk
    # multiple -- a 512 chunk would pad the slice straight back to the
    # full width and erase the saving this benchmark measures
    engine, state0 = get_engine("fused").simulated(
        w, params, scale_chunk=128, impl="pallas", scope=scope,
    )
    loss_fn = make_mlp_loss(class_weights("balanced"))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, inv_sqrt(alpha0), cfg, engine=engine)
    )
    state = init_fl_state(cfg, state0, engine=engine)
    m = {}
    for _ in range(rounds):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)

    view = engine.params_view(state.params)
    consensus = jax.tree_util.tree_map(lambda p: jnp.mean(p, axis=0), view)
    # per-hospital DEPLOYED model: node i's own row -- under a partial
    # scope that is the gossiped backbone + its private head; under full
    # scope it is (approximately) the consensus model itself
    per_h_own, per_h_cons = [], []
    for i in range(n):
        p_i = jax.tree_util.tree_map(lambda p, i=i: p[i], view)
        x_i = jnp.asarray(data.features[i])
        y_i = jnp.asarray(data.labels[i])
        per_h_own.append(float(mlp_balanced_accuracy(p_i, x_i, y_i)))
        per_h_cons.append(float(mlp_balanced_accuracy(consensus, x_i, y_i)))

    layout = engine.layout
    wire_layout = engine.wire_layout
    return {
        "name": name,
        "scope": engine.scope.spec(),
        "n_nodes": n,
        "q": q,
        "scale_chunk": 128,
        "topk": None,
        "rounds": rounds,
        "iterations": int(state.step),
        "bal_acc_per_hospital_mean": float(np.mean(per_h_own)),
        "bal_acc_per_hospital_min": float(np.min(per_h_own)),
        "bal_acc_consensus_per_hospital_mean": float(np.mean(per_h_cons)),
        "per_hospital_bal_acc": [round(v, 4) for v in per_h_own],
        "final_loss": float(m["loss"]),
        "consensus_err": float(m["consensus_err"]),
        # the wire-byte columns tools/bench_guard.py gates: the scoped
        # wire ships only the shared slice's columns
        "wire_bytes_per_round": float(m["wire_bytes"]),
        "wire_total_cols": int(wire_layout.total),
        "layout_total_cols": int(layout.total),
        "shared_fraction": wire_layout.total / layout.total,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=150,
                    help="comm rounds per cell (equal budget everywhere)")
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--out", default="experiments/personalization_ehr.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few rounds, accuracies NOT "
                         "representative -- exercises every cell, the "
                         "wire-linearity identity, and the JSON schema")
    args = ap.parse_args()
    rounds = 6 if args.smoke else args.rounds

    rows = []

    def cell(name, scope):
        row = run_cell(name, scope, rounds, args.q)
        rows.append(row)
        print(f"{name:18s} per-hosp bal_acc={row['bal_acc_per_hospital_mean']:.3f} "
              f"(min {row['bal_acc_per_hospital_min']:.3f}) "
              f"consensus={row['bal_acc_consensus_per_hospital_mean']:.3f} "
              f"wire={row['wire_bytes_per_round']:.0f}B "
              f"({row['shared_fraction']:.2f} of full)")
        return row

    full = cell("full", None)
    backbone = cell("backbone", "backbone")
    layerwise = cell("layerwise_freq4", "layerwise:freq=4")

    # the shared-fraction x full-wire identity, exact to the byte:
    # flat_wire_bytes is LINEAR in the layout total
    assert (backbone["wire_bytes_per_round"] * full["layout_total_cols"]
            == full["wire_bytes_per_round"] * backbone["wire_total_cols"]), (
        backbone["wire_bytes_per_round"], full["wire_bytes_per_round"])
    # the round-gated layerwise scope ships the FULL wire
    assert layerwise["wire_bytes_per_round"] == full["wire_bytes_per_round"]
    # partial federation must be STRICTLY cheaper on the wire
    assert backbone["wire_bytes_per_round"] < full["wire_bytes_per_round"]
    if not args.smoke:
        # the personalization claim on the label-shifted cohort
        assert (backbone["bal_acc_per_hospital_mean"]
                >= full["bal_acc_per_hospital_mean"]), (
            backbone["bal_acc_per_hospital_mean"],
            full["bal_acc_per_hospital_mean"])

    data = _hard_cohort(0)
    record = {
        "experiment": "personalization_vs_consensus_ehr",
        "cohort": "hardened hospital20 (2103 AD / 7919 MCI, 42 features; "
                  f"label_shift={LABEL_SHIFT}, "
                  f"minority_concentration={MINORITY_CONCENTRATION}, "
                  f"conditional_shift={CONDITIONAL_SHIFT})",
        "cohort_stats": cohort_label_stats(data.labels),
        "algorithm": "dsgt (fused engine, int8 wire, class-weighted loss)",
        "alpha": "0.01/sqrt(r)",
        "smoke": bool(args.smoke),
        "note": "mean per-hospital balanced accuracy of each hospital's "
                "DEPLOYED model (own row: gossiped backbone + private "
                "head under partial scope). backbone >= full is asserted "
                "in-script (non-smoke) with strictly fewer wire bytes; "
                "the scoped wire obeys wire_scoped * total_full == "
                "wire_full * total_scoped exactly, and layerwise ships "
                "the full wire (the gate changes the mix, not the "
                "collective). tools/bench_guard.py gates the wire-byte "
                "columns.",
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
