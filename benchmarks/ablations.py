"""Beyond-paper ablations on the paper's algorithm.

1. TOPOLOGY: convergence at a fixed communication budget across graphs
   with different spectral gaps (ring < hospital20 < torus < complete).
   Theory: consensus error contracts at rate |lambda_2(W)|, so at equal
   comm rounds a larger spectral gap should reach lower consensus error;
   loss differences stay small once the gap is "good enough" -- which is
   why the TPU torus (gap 0.4 at N=16) is a sound substitute for the
   paper's arbitrary hospital graph.

2. CLIENT DRIFT vs Q: FD's local steps save communication but let nodes
   drift toward their LOCAL optima between mixes (the FedAvg-style drift
   the paper leaves open for Q>1 theory). We sweep Q under increasing
   data heterogeneity and report the consensus-model loss penalty at a
   fixed ITERATION budget -- quantifying when the paper's Q=100 is safe.
"""

from __future__ import annotations

import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FLConfig,
    consensus_params,
    init_fl_state,
    make_dense_gossip,
    make_fl_round,
    mixing_matrix,
    spectral_gap,
)
from repro.core.schedules import constant

N, D = 16, 12


def _problem(heterogeneity: float, seed: int = 0):
    """Per-node quadratics with DIFFERENT curvatures A_i and optima b_i.

    With identical Hessians local SGD commutes with averaging and FD shows
    NO drift (verified -- the first version of this ablation measured
    exactly 1.00x penalties); heterogeneous curvature is what makes Q>1
    drift toward local optima, matching the non-convex intuition.
    """
    rng = np.random.default_rng(seed)
    common = rng.normal(size=(D,))
    local = heterogeneity * rng.normal(size=(N, D))
    targets = jnp.asarray(common[None] + local, jnp.float32)
    hessians = []
    for i in range(N):
        m = rng.normal(size=(D, D)) * (0.2 + 0.1 * heterogeneity)
        hessians.append(np.eye(D) + m @ m.T / D)
    a = jnp.asarray(np.stack(hessians), jnp.float32)  # (N, D, D)

    def loss(params, batch):
        r = params["x"] - batch["t"] - batch["noise"]
        return 0.5 * r @ batch["a"] @ r

    return targets, a, loss


def _run(topology: str, q: int, heterogeneity: float, iters: int, alpha: float,
         seed: int = 0, algorithm: str = "dsgt") -> Dict[str, float]:
    targets, a, loss = _problem(heterogeneity, seed)
    w = mixing_matrix(topology, N)
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=N)
    rf = jax.jit(make_fl_round(loss, make_dense_gossip(w), constant(alpha), cfg))
    state = init_fl_state(cfg, {"x": jnp.zeros((N, D))})
    rng = np.random.default_rng(seed + 1)
    rounds = iters // q
    m = {}
    for _ in range(rounds):
        batch = {
            "t": jnp.broadcast_to(targets, (q, N, D)),
            "a": jnp.broadcast_to(a, (q, N, D, D)),
            "noise": jnp.asarray(0.3 * rng.normal(size=(q, N, D)), jnp.float32),
        }
        state, m = rf(state, batch)
    xbar = consensus_params(state)["x"]
    # the true global optimum of (1/N) sum f_i: solves (sum A_i) x = sum A_i b_i
    an = np.asarray(a)
    bn = np.asarray(targets)
    opt = np.linalg.solve(an.sum(0), np.einsum("nij,nj->i", an, bn))
    return {
        "consensus_err": float(m["consensus_err"]),
        "dist_to_opt": float(np.linalg.norm(np.asarray(xbar) - opt)),
        "comm_rounds": rounds,
        "spectral_gap": spectral_gap(w),
    }


def topology_ablation(iters: int = 300) -> Dict:
    """DSGD's steady-state consensus error scales ~alpha*zeta/gap (zeta =
    gradient heterogeneity); DSGT's does not -- so DSGD is the probe that
    exposes the topology, and the DSGT column shows GT erasing the
    difference (why the paper prefers it for arbitrary hospital graphs)."""
    print("topology ablation (Q=1, equal comm budget, N=16):")
    out = {}
    for topo in ("ring", "erdos_renyi", "torus:4x4", "complete"):
        r_d = _run(topo, q=1, heterogeneity=2.0, iters=iters, alpha=0.05, algorithm="dsgd")
        r_t = _run(topo, q=1, heterogeneity=2.0, iters=iters, alpha=0.05, algorithm="dsgt")
        out[topo] = {"spectral_gap": r_d["spectral_gap"],
                     "dsgd_consensus": r_d["consensus_err"],
                     "dsgt_consensus": r_t["consensus_err"],
                     "dsgd_dist": r_d["dist_to_opt"], "dsgt_dist": r_t["dist_to_opt"]}
        print(f"  {topo:12s} gap={r_d['spectral_gap']:.3f} "
              f"DSGD consensus={r_d['consensus_err']:.2e}  DSGT consensus={r_t['consensus_err']:.2e}")
    ordered = sorted(out.values(), key=lambda r: r["spectral_gap"])
    mono = all(a["dsgd_consensus"] >= b["dsgd_consensus"] * 0.8
               for a, b in zip(ordered, ordered[1:]))
    print(f"  DSGD consensus error decreases with spectral gap: {mono}")
    return out


def drift_ablation(iters: int = 240) -> Dict:
    print("client-drift vs Q (DSGT, fixed iteration budget, N=16 ring):")
    out = {}
    for het in (0.5, 2.0, 8.0):
        row = {}
        for q in (1, 4, 16, 60):
            r = _run("ring", q=q, heterogeneity=het, iters=iters, alpha=0.05)
            row[q] = r["dist_to_opt"]
        penalty = row[60] / max(row[1], 1e-9)
        out[str(het)] = {"dist_by_q": row, "q60_penalty": penalty}
        print(f"  heterogeneity={het:4.1f}: dist(Q=1)={row[1]:.4f} dist(Q=4)={row[4]:.4f} "
              f"dist(Q=16)={row[16]:.4f} dist(Q=60)={row[60]:.4f}  (Q=60 penalty {penalty:.2f}x)")
    return out


def main() -> Dict:
    return {"topology": topology_ablation(), "drift": drift_ablation()}


if __name__ == "__main__":
    res = main()
    with open("experiments/ablations.json", "w") as f:
        json.dump(res, f, indent=2)
