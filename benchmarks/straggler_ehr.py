"""Straggler-tolerance experiment -> experiments/straggler_ehr.json.

Quantifies the depth-k bounded-staleness x straggler-fraction frontier in
model quality on the paper's 20-hospital cohort: FD-DSGT with the fused
engine under ``BoundedStalenessSchedule(k)`` (k wire payloads in flight,
the mix consumes k-round-stale neighbor information) composed with the
``stragglers`` NodeProgram (each round a random ``frac`` of hospitals is
slow: it runs half its local steps and its payload misses the round,
the lost mixing weight folded into the self-loops by the symmetric
drop-renormalization).

The headline: a straggler budget of k rounds is nearly free. Staleness
deepens the gossip recurrence (the depth-k delay polynomial's
disagreement-mode roots approach the unit circle as k grows but stay
inside it on the hospital graph's Metropolis W), and payload drops
shrink the expected spectral gap by ~uptime^2 -- both slow CONSENSUS,
neither touches local optimization, so balanced accuracy degrades
within run-to-run noise (<= 0.02 asserted at k <= 4 with 25% stragglers
in tests/test_bounded_staleness.py) until staleness depth and drop rate
compound.

Also reports the staleness/churn-aware step-size controller
(``schedules.robust_alpha_scale``: alpha scaled by uptime^2 * 2/(2+k))
on the harshest frontier cell, separating "the run is unstable" from
"the run just needs a smaller step".

Usage: PYTHONPATH=src python benchmarks/straggler_ehr.py \
           [--rounds 80] [--q 10] [--out experiments/straggler_ehr.json]
       PYTHONPATH=src python benchmarks/straggler_ehr.py --smoke  # tiny CI run
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ehr_mlp import class_weights
from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.schedules import inv_sqrt, robust_alpha_scale, scaled
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import make_mlp_loss, mlp_balanced_accuracy, mlp_init
from repro.training.trainer import stack_for_nodes

#: staleness depths swept (0 == the sequential baseline; 1 == pipelined)
STALENESS_DEPTHS = (0, 1, 2, 4)
#: straggler fractions swept (0.0 == the homogeneous lockstep baseline)
STRAGGLER_FRACTIONS = (0.0, 0.25, 0.5)
STRAGGLER_RATE = 0.5  # a slow node runs half its local steps


def run_cell(k: int, frac: float, rounds: int, q: int, seed: int = 0,
             robust_alpha: bool = False, alpha0: float = 0.01) -> dict:
    """One (staleness depth, straggler fraction) cell: FD-DSGT, fused
    engine, hospital graph, equal round budget everywhere."""
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)
    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    node_program = (
        None if frac == 0.0 else
        f"stragglers:frac={frac},rate={STRAGGLER_RATE},drop=1,seed=0"
    )
    engine, state0 = get_engine("fused").simulated(
        w, params, scale_chunk=512, impl="pallas",
        round_schedule=("sequential" if k == 0
                        else f"bounded_staleness:k={k}"),
        node_program=node_program,
    )
    sched = inv_sqrt(alpha0)
    if robust_alpha:
        uptime = engine.node_program.expected_uptime()
        sched = scaled(sched, robust_alpha_scale(uptime, k))
    loss_fn = make_mlp_loss(class_weights("balanced"))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, sched, cfg, engine=engine)
    )
    state = init_fl_state(cfg, state0, engine=engine)
    m, payload_fracs, compute_fracs = {}, [], []
    for _ in range(rounds):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
        if "payload_fraction" in m:
            payload_fracs.append(float(m["payload_fraction"]))
        if "compute_fraction" in m:
            compute_fracs.append(float(m["compute_fraction"]))
    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    return {
        "staleness_depth": k,
        "straggler_fraction": frac,
        "schedule": engine.round_schedule.spec(),
        "node_program": engine.node_program.spec(),
        "robust_alpha": bool(robust_alpha),
        "rounds": rounds,
        "q": q,
        "iterations": int(state.step),
        "bal_acc": float(mlp_balanced_accuracy(consensus, xall, yall)),
        "final_loss": float(m["loss"]),
        "consensus_err": float(m["consensus_err"]),
        "mean_payload_fraction": (
            float(np.mean(payload_fracs)) if payload_fracs else 1.0
        ),
        "mean_compute_fraction": (
            float(np.mean(compute_fracs)) if compute_fracs else 1.0
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=80,
                    help="comm rounds per cell (equal budget everywhere)")
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--out", default="experiments/straggler_ehr.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few rounds, numbers NOT "
                         "representative -- exercises every cell and the "
                         "JSON schema")
    args = ap.parse_args()
    rounds = 6 if args.smoke else args.rounds

    cells = []
    for frac in STRAGGLER_FRACTIONS:
        for k in STALENESS_DEPTHS:
            cell = run_cell(k, frac, rounds, args.q)
            cells.append(cell)
            print(f"k={k} frac={frac:4.2f} "
                  f"payload~{cell['mean_payload_fraction']:.2f} "
                  f"compute~{cell['mean_compute_fraction']:.2f} "
                  f"bal_acc={cell['bal_acc']:.3f} "
                  f"cons_err={cell['consensus_err']:.2e}")

    # the alpha controller on the harshest frontier cell
    k_max, frac_max = STALENESS_DEPTHS[-1], STRAGGLER_FRACTIONS[-1]
    ctrl = run_cell(k_max, frac_max, rounds, args.q, robust_alpha=True)
    cells.append(ctrl)
    print(f"k={k_max} frac={frac_max} + robust_alpha "
          f"bal_acc={ctrl['bal_acc']:.3f} "
          f"cons_err={ctrl['consensus_err']:.2e}")

    baseline = cells[0]["bal_acc"]  # k=0, homogeneous
    summary = {}
    for frac in STRAGGLER_FRACTIONS:
        summary[f"frac={frac}"] = {
            f"k={c['staleness_depth']}": {
                "bal_acc": c["bal_acc"],
                "bal_acc_delta_vs_lockstep": c["bal_acc"] - baseline,
            }
            for c in cells
            if c["straggler_fraction"] == frac and not c["robust_alpha"]
        }

    record = {
        "experiment": "straggler_bounded_staleness_ehr",
        "cohort": "hospital20 (2103 AD / 7919 MCI, 42 features)",
        "algorithm": "dsgt (fused engine, int8 wire, class-weighted loss)",
        "alpha": "0.01/sqrt(r)",
        "straggler_rate": STRAGGLER_RATE,
        "smoke": bool(args.smoke),
        "note": "equal round budget per cell; bounded_staleness:k keeps "
                "k payloads in flight (the mix is k rounds stale; wire "
                "bytes per round unchanged -- tools/bench_guard.py), "
                "stragglers:frac drops that fraction of payloads per "
                "round AND halves their local steps (masked scan "
                "iterations of the ONE compiled round; zero recompiles, "
                "tests/test_heterogeneity.py). Degradation <= 0.02 at "
                "k <= 4 with 25% stragglers is asserted in "
                "tests/test_bounded_staleness.py.",
        "cells": cells,
        "summary": summary,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
