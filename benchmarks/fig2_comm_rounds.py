"""Paper Fig. 2 reproduction: convergence vs COMMUNICATION ROUNDS.

Trains the paper's shallow NN on the synthetic 20-hospital EHR cohort with
the paper's hyperparameters (m=20, Q=100 for FD variants, alpha=0.02/sqrt r,
hospital graph) and reports, per algorithm, the loss / stationarity /
consensus trajectories indexed by communication rounds.

The paper's qualitative claims validated here:
  1. FD-DSGD / FD-DSGT converge ~Q x faster per communication round;
  2. DSGT reaches a smaller optimality gap than DSGD (non-IID data);
  3. all four reach comparable loss at a matched ITERATION budget.
"""

from __future__ import annotations

import json
from typing import Dict

import jax
import numpy as np

from repro.configs import FLRunConfig
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import mlp_accuracy, mlp_init, mlp_loss
from repro.training.trainer import train_decentralized

ALGOS = {
    "DSGD": ("dsgd", 1),
    "DSGT": ("dsgt", 1),
    "FD-DSGD (Q=100)": ("dsgd", 100),
    "FD-DSGT (Q=100)": ("dsgt", 100),
}


def run(iterations: int = 3000, m: int = 20, seed: int = 0, log: bool = True) -> Dict:
    data = generate_ehr_cohort(seed=seed)
    xall = np.concatenate(data.features)
    yall = np.concatenate(data.labels)
    results = {}
    for name, (algo, q) in ALGOS.items():
        run_cfg = FLRunConfig(
            algorithm=algo, q=q, topology="hospital20", n_nodes=20,
            batch_per_node=m, alpha0=0.02, schedule="inv_sqrt", seed=seed,
        )
        res = train_decentralized(
            mlp_loss, mlp_init(jax.random.key(seed)), run_cfg,
            make_node_batcher(data, m=m, seed=seed + 1),
            rounds=max(1, iterations // q),
        )
        h = res.history
        import jax.numpy as jnp

        acc = float(mlp_accuracy(res.consensus, jnp.asarray(xall), jnp.asarray(yall)))
        results[name] = {
            "comm_rounds": h.column("comm_rounds").tolist(),
            "loss": h.column("loss").tolist(),
            "grad_norm_sq": h.column("grad_norm_sq").tolist(),
            "consensus_err": h.column("consensus_err").tolist(),
            "iterations": int(h.last()["iteration"]),
            "final_loss": h.last()["loss"],
            "final_acc": acc,
        }
        if log:
            print(
                f"  {name:18s} comm_rounds={int(h.last()['comm_rounds']):5d} "
                f"iters={results[name]['iterations']:5d} "
                f"loss={results[name]['final_loss']:.4f} acc={acc:.3f}"
            )
    return results


def comm_rounds_to_loss(res: Dict, target: float) -> Dict[str, float]:
    out = {}
    for name, r in res.items():
        rounds = np.asarray(r["comm_rounds"])
        losses = np.asarray(r["loss"])
        hit = np.nonzero(losses <= target)[0]
        out[name] = float(rounds[hit[0]]) if len(hit) else float("inf")
    return out


def main(iterations: int = 3000) -> Dict:
    print("Fig. 2 reproduction (synthetic cohort, paper hyperparameters):")
    res = run(iterations=iterations)
    target = 1.10 * max(res["DSGT"]["final_loss"], res["DSGD"]["final_loss"])
    to_target = comm_rounds_to_loss(res, target)
    print(f"  comm rounds to reach loss<={target:.4f}: "
          + ", ".join(f"{k}={v:.0f}" for k, v in to_target.items()))
    speedup = to_target["DSGT"] / max(to_target["FD-DSGT (Q=100)"], 1.0)
    print(f"  FD-DSGT communication saving vs DSGT: {speedup:.0f}x")
    res["_derived"] = {"comm_rounds_to_target": to_target, "fd_dsgt_saving": speedup}
    return res


if __name__ == "__main__":
    out = main()
    with open("experiments/fig2_results.json", "w") as f:
        json.dump(out, f)
