"""Flat-buffer vs per-leaf gossip micro-benchmark -> BENCH_gossip.json.

Measures the tentpole claims on a many-leaf synthetic node-stacked state
(64 nodes x 192 leaves -- the leaf-count profile of a real transformer
pytree, where most leaves are small: norms, biases, per-head slices):

  * dense gossip:       one (N, N) @ (N, total) matmul on the packed
                        buffer vs one einsum per leaf;
  * compressed gossip:  one fused quantize-mix-EF pass on the flat buffer
                        (the Pallas kernel's bit-identical jnp oracle) vs
                        per-leaf quantize + matmul + EF;
  * FL round:           a full DSGD round (Q=4) with flat state threading
                        (make_fl_round(engine=FlatEngine(...))) vs tree
                        state;
  * fused round:        the round megakernel's comm step (ONE fused
                        update+quantize+mix+EF call; two wires for DSGT)
                        vs the pre-megakernel update-then-mix flat path
                        (the update as one jit, then one compressed-gossip
                        jit per wire, compression state threaded through
                        Python at the driver level -- the only way to run
                        a compressed comm round before the megakernel);
  * top-k wire:         the fused round with top-k payload sparsification
                        (k columns per scale chunk inside the kernel, EF
                        absorbing the truncation) vs the dense-int8 wire:
                        per-round wire bytes (values + positions + scales
                        accounting, packing.flat_wire_bytes) and step
                        time.

Methodology (honest measurement on a noisy shared CPU): the first three
rows run ROUNDS consecutive rounds inside ONE jitted lax.scan -- the
steady-state per-round cost of the computation graph itself, with
per-call dispatch amortized away, exactly how a training loop consumes
the engine. The fused-round row CANNOT use that harness for its baseline:
the pre-megakernel path is forced through Python between its stages
(that is precisely what the megakernel removes), so both sides of that
row are timed as per-round dispatch loops with donated buffers (how a
training loop consumes state), gradients precomputed since the grad
evaluation is identical on both sides. All variants are timed INTERLEAVED
over several trials and the median is reported, so slow-container drift
hits both sides equally. The Pallas kernels run in interpret mode
(Python) on CPU, so fused paths are timed via their jnp oracles; the
kernels' additional TPU win (no materialized h/payload/dq/recon HBM
round-trips) is a roofline argument, not a CPU wall-time one.

Usage: PYTHONPATH=src python benchmarks/gossip_bench.py [--out BENCH_gossip.json]
       PYTHONPATH=src python benchmarks/gossip_bench.py --smoke   # tiny CI shapes
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    init_compression_state,
    init_flat_compression_state,
    make_compressed_dense_gossip_per_leaf,
    make_compressed_flat_gossip,
)
from repro.core.engine import FlatEngine
from repro.core.fl import FLConfig, init_fl_state, make_fl_round
from repro.core.mixing import (
    make_dense_flat_mix,
    make_dense_gossip,
    make_dense_gossip_per_leaf,
)
from repro.core.packing import flat_wire_bytes, pack
from repro.core.schedules import constant
from repro.core.topology import mixing_matrix

N_NODES = 64
N_LEAVES = 192
SCALE_CHUNK = 512
TOPK = 64  # top-k row: 64 of 512 columns per chunk on the wire
ROUNDS = 50
TRIALS = 9


def make_state(n_nodes: int = N_NODES, n_leaves: int = N_LEAVES) -> Dict:
    """Synthetic many-leaf node-stacked state: mixed ranks, mostly small
    leaves (the shape profile of a real parameter pytree)."""
    rng = np.random.default_rng(0)
    tree = {}
    for i in range(n_leaves):
        shape = [(n_nodes, 16), (n_nodes, 8), (n_nodes, 4, 8), (n_nodes, 8)][i % 4]
        tree[f"leaf_{i:03d}"] = jnp.asarray(rng.normal(size=shape), jnp.float32)
    return tree


def _scan_runner(step: Callable, rounds: int) -> Callable:
    """jit(scan) of `rounds` applications of a (carry -> carry) step."""

    @jax.jit
    def run(carry):
        return jax.lax.scan(lambda c, _: (step(c), None), carry, None, length=rounds)[0]

    return run


def time_interleaved(variants: Dict[str, tuple], rounds: int = None,
                     trials: int = None) -> Dict[str, float]:
    """Median per-round us for {name: (step_fn, init_carry)}, variants
    interleaved within each trial so container noise hits all equally.
    ``rounds``/``trials`` default to the module knobs (resolved at call
    time so --smoke can shrink them)."""
    rounds = ROUNDS if rounds is None else rounds
    trials = TRIALS if trials is None else trials
    runners = {k: (_scan_runner(fn, rounds), init) for k, (fn, init) in variants.items()}
    for run, init in runners.values():  # compile + warm
        jax.block_until_ready(run(init))
    samples = {k: [] for k in runners}
    for _ in range(trials):
        for k, (run, init) in runners.items():
            t0 = time.perf_counter()
            jax.block_until_ready(run(init))
            samples[k].append((time.perf_counter() - t0) / rounds * 1e6)
    return {k: float(np.median(v)) for k, v in samples.items()}


def bench_dense(tree, w) -> Dict:
    flat_buf, layout = pack(tree)
    us = time_interleaved({
        "per_leaf": (make_dense_gossip_per_leaf(w), tree),
        "flat": (make_dense_flat_mix(w), flat_buf),
    })
    return {
        "name": "dense_gossip",
        "n_nodes": flat_buf.shape[0],
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "total_params": layout.used,
        "us_per_leaf": us["per_leaf"],
        "us_flat": us["flat"],
        "speedup_flat": us["per_leaf"] / us["flat"],
    }


def bench_compressed(tree, w) -> Dict:
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    g_leaf = make_compressed_dense_gossip_per_leaf(w)
    g_flat = make_compressed_flat_gossip(w, scale_chunk=SCALE_CHUNK)

    def step_leaf(carry):
        return g_leaf(*carry)

    def step_flat(carry):
        return g_flat(*carry)

    us = time_interleaved({
        "per_leaf": (step_leaf, (tree, init_compression_state(tree))),
        "flat": (step_flat, (flat_buf, init_flat_compression_state(flat_buf))),
    })
    return {
        "name": "compressed_gossip",
        "n_nodes": flat_buf.shape[0],
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "total_params": layout.total,
        "us_per_leaf": us["per_leaf"],
        "us_flat": us["flat"],
        "speedup_flat": us["per_leaf"] / us["flat"],
        "wire_bytes_per_neighbor": flat_wire_bytes(layout, 1, SCALE_CHUNK),
    }


def bench_fl_round(tree, w, q: int = 4) -> Dict:
    n_nodes = w.shape[0]

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n_nodes), jnp.float32)}
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n_nodes)
    sched = constant(0.01)

    rf_tree = make_fl_round(loss_fn, make_dense_gossip(w), sched, cfg)
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    flat_engine = FlatEngine(make_dense_flat_mix(w), layout)
    rf_flat = make_fl_round(loss_fn, None, sched, cfg, engine=flat_engine)

    us = time_interleaved({
        "tree": (lambda st: rf_tree(st, batches)[0], init_fl_state(cfg, tree)),
        "flat": (lambda st: rf_flat(st, batches)[0], init_fl_state(cfg, flat_buf)),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": f"fl_round_dsgd_q{q}",
        "n_nodes": n_nodes,
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "us_tree_state": us["tree"],
        "us_flat_state": us["flat"],
        "speedup_flat": us["tree"] / us["flat"],
        "note": "the flat round re-materializes the tree view inside the "
                "per-node loss every local step (unpack + grad pack), which "
                "XLA CPU lowers to real concats; on TPU these fuse. The "
                "gossip/update/metric steps themselves are the dense_gossip "
                "row's flat path.",
    }


def bench_fused_round(tree, w, algorithm: str, rounds: int = 200,
                      trials: int = 9) -> Dict:
    """Round-megakernel comm step (one fused call) vs the pre-megakernel
    update-then-mix flat path (update jit + one compressed-gossip jit per
    wire, state threaded through Python). Both sides: donated buffers,
    per-round dispatch, precomputed flat gradients (identical grad work on
    both sides is excluded so the row measures the fused machinery)."""
    from repro.kernels.gossip.ref import fused_round_gt_ref, fused_round_ref

    from repro.core.mixing import _split_w

    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    w_self, w_off = _split_w(w)
    alpha = jnp.float32(0.01)
    rng = np.random.default_rng(1)
    g = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    gp = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    tr = jnp.asarray(0.3 * rng.normal(size=(n, t)), jnp.float32)
    zeros = lambda: jnp.zeros((n, t), jnp.float32)

    gfn = make_compressed_flat_gossip(w, scale_chunk=SCALE_CHUNK)
    gossip = jax.jit(lambda h, c: gfn(h, c), donate_argnums=(0, 1))

    if algorithm == "dsgd":
        fused = jax.jit(
            lambda x, r, s: fused_round_ref(
                x, g, r, s, w_off, w_self, alpha, scale_chunk=SCALE_CHUNK
            ),
            donate_argnums=(0, 1, 2),
        )
        upd = jax.jit(lambda x: x - alpha * g, donate_argnums=(0,))

        def run_fused(rounds):
            x, r, s = flat_buf + 0, zeros(), zeros()
            for _ in range(rounds):
                x, r, s, _ = fused(x, r, s)
            jax.block_until_ready(x)

        def run_unfused(rounds):
            x, c = flat_buf + 0, {"recon": zeros(), "residual": zeros()}
            for _ in range(rounds):
                h = upd(x)
                x, c = gossip(h, c)
            jax.block_until_ready(x)

        dispatches = 2
    else:
        fused = jax.jit(
            lambda x, tk, rx, sx, rt, st: fused_round_gt_ref(
                x, tk, g, gp, rx, sx, rt, st, w_off, w_self, alpha,
                scale_chunk=SCALE_CHUNK,
            ),
            donate_argnums=(0, 1, 2, 3, 4, 5),
        )
        upd = jax.jit(
            lambda x, tk: (tk + g - gp, x - alpha * (tk + g - gp)),
            donate_argnums=(0, 1),
        )

        def run_fused(rounds):
            x, tk = flat_buf + 0, tr + 0
            rx, sx, rt, st = zeros(), zeros(), zeros(), zeros()
            for _ in range(rounds):
                x, tk, rx, sx, rt, st, _, _ = fused(x, tk, rx, sx, rt, st)
            jax.block_until_ready(x)

        def run_unfused(rounds):
            x, tk = flat_buf + 0, tr + 0
            cx = {"recon": zeros(), "residual": zeros()}
            ct = {"recon": zeros(), "residual": zeros()}
            for _ in range(rounds):
                th, h = upd(x, tk)
                x, cx = gossip(h, cx)
                tk, ct = gossip(th, ct)
            jax.block_until_ready(x)

        dispatches = 3

    run_fused(10), run_unfused(10)  # compile + warm
    samples = {"fused": [], "update_then_mix": []}
    for _ in range(trials):
        for name, fn in (("fused", run_fused), ("update_then_mix", run_unfused)):
            t0 = time.perf_counter()
            fn(rounds)
            samples[name].append((time.perf_counter() - t0) / rounds * 1e6)
    us = {k: float(np.median(v)) for k, v in samples.items()}
    wires = 2 if algorithm == "dsgt" else 1
    return {
        "name": f"fused_round_{algorithm}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "us_fused": us["fused"],
        "us_update_then_mix": us["update_then_mix"],
        "speedup_fused": us["update_then_mix"] / us["fused"],
        "dispatches_fused": 1,
        "dispatches_update_then_mix": dispatches,
        "wire_bytes_per_neighbor": wires * flat_wire_bytes(layout, 1, SCALE_CHUNK),
        "note": "comm-step machinery only (grad eval identical on both "
                "sides); per-round dispatch with donated buffers -- the "
                "pre-megakernel path is forced through Python between its "
                "stages, which is exactly what the megakernel removes. "
                "jnp-oracle timing on CPU; the Pallas kernel's VMEM win is "
                "a TPU roofline argument.",
    }




def bench_topk_wire(tree, w, algorithm: str, topk: int = TOPK,
                    rounds: int = 200, trials: int = 9) -> Dict:
    """Top-k sparsified wire vs the dense-int8 wire, same fused round
    machinery (jnp oracle on CPU, donated-buffer dispatch loop). Reports
    measured step time and the per-round wire bytes of each
    (values + position encoding + scales for top-k; see
    packing.flat_wire_bytes). The CPU step-time delta is the in-kernel
    sort cost; the wire-byte column is the point -- the payload drops
    below the int8 floor while EF keeps the mixing contraction
    (tests/test_topk_property.py property-tests consensus under top-k)."""
    from repro.kernels.gossip.ref import fused_round_gt_ref, fused_round_ref

    from repro.core.mixing import _split_w

    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    w_self, w_off = _split_w(w)
    alpha = jnp.float32(0.01)
    rng = np.random.default_rng(3)
    g = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    gp = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    tr = jnp.asarray(0.3 * rng.normal(size=(n, t)), jnp.float32)
    zeros = lambda: jnp.zeros((n, t), jnp.float32)

    def make_runner(k):
        if algorithm == "dsgd":
            step = jax.jit(
                lambda x, r, s: fused_round_ref(
                    x, g, r, s, w_off, w_self, alpha, scale_chunk=SCALE_CHUNK,
                    topk=k,
                ),
                donate_argnums=(0, 1, 2),
            )

            def run(nr):
                x, r, s = flat_buf + 0, zeros(), zeros()
                for _ in range(nr):
                    x, r, s, _ = step(x, r, s)
                jax.block_until_ready(x)
        else:
            step = jax.jit(
                lambda x, tk, rx, sx, rt, st: fused_round_gt_ref(
                    x, tk, g, gp, rx, sx, rt, st, w_off, w_self, alpha,
                    scale_chunk=SCALE_CHUNK, topk=k,
                ),
                donate_argnums=(0, 1, 2, 3, 4, 5),
            )

            def run(nr):
                x, tk = flat_buf + 0, tr + 0
                rx, sx, rt, st = zeros(), zeros(), zeros(), zeros()
                for _ in range(nr):
                    x, tk, rx, sx, rt, st, _, _ = step(x, tk, rx, sx, rt, st)
                jax.block_until_ready(x)
        return run

    runners = {"int8": make_runner(None), "topk": make_runner(topk)}
    for r in runners.values():
        r(10)  # compile + warm
    samples = {k: [] for k in runners}
    for _ in range(trials):
        for name, fn in runners.items():
            t0 = time.perf_counter()
            fn(rounds)
            samples[name].append((time.perf_counter() - t0) / rounds * 1e6)
    us = {k: float(np.median(v)) for k, v in samples.items()}
    wires = 2 if algorithm == "dsgt" else 1
    int8_bytes = wires * flat_wire_bytes(layout, 1, SCALE_CHUNK)
    topk_bytes = wires * flat_wire_bytes(layout, 1, SCALE_CHUNK, topk)
    return {
        "name": f"topk_wire_{algorithm}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "topk": topk,
        "us_int8": us["int8"],
        "us_topk": us["topk"],
        "wire_bytes_per_neighbor_int8": int8_bytes,
        "wire_bytes_per_neighbor_topk": topk_bytes,
        "wire_reduction_vs_int8": int8_bytes / topk_bytes,
        "note": "same fused round, payload masked to the k largest "
                "columns per scale chunk inside the kernel; wire bytes = "
                "k int8 values + min(2k, chunk/8) position bytes + 4 B "
                "scale per chunk. EF absorbs the truncation. jnp-oracle "
                "timing on CPU (the sort is in-tile on TPU).",
    }

def main() -> List[Dict]:
    global ROUNDS, TRIALS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_gossip.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few rounds: the CI smoke that "
                         "exercises every row (numbers are NOT "
                         "representative; the committed BENCH_gossip.json "
                         "is the full run)")
    args = ap.parse_args()

    if args.smoke:
        ROUNDS, TRIALS = 5, 3
        tree = make_state(n_nodes=8, n_leaves=12)
        w = mixing_matrix("torus:4x2", 8)
        fused_rounds, fused_trials = 10, 3
    else:
        tree = make_state()
        w = mixing_matrix("torus:8x8", N_NODES)
        fused_rounds, fused_trials = 200, 9

    rows = [
        bench_dense(tree, w),
        bench_compressed(tree, w),
        bench_fl_round(tree, w),
        bench_fused_round(tree, w, "dsgd", fused_rounds, fused_trials),
        bench_fused_round(tree, w, "dsgt", fused_rounds, fused_trials),
        # fewer samples: the row's point is the wire-byte column; the CPU
        # step time only prices the jnp-oracle sort (in-tile on TPU)
        bench_topk_wire(tree, w, "dsgd", rounds=min(fused_rounds, 40),
                        trials=min(fused_trials, 5)),
        bench_topk_wire(tree, w, "dsgt", rounds=min(fused_rounds, 40),
                        trials=min(fused_trials, 5)),
    ]
    for r in rows:
        extras = {k: v for k, v in r.items() if isinstance(v, float)}
        print(f"  {r['name']:22s} " + "  ".join(f"{k}={v:10.1f}" for k, v in extras.items()))

    record = {
        "bench": "gossip_flat_vs_per_leaf",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "rounds_per_sample": ROUNDS,
        "trials": TRIALS,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
