"""Flat-buffer vs per-leaf gossip micro-benchmark -> BENCH_gossip.json.

Measures the tentpole claims on a many-leaf synthetic node-stacked state
(64 nodes x 192 leaves -- the leaf-count profile of a real transformer
pytree, where most leaves are small: norms, biases, per-head slices):

  * dense gossip:       one (N, N) @ (N, total) matmul on the packed
                        buffer vs one einsum per leaf;
  * compressed gossip:  one fused quantize-mix-EF pass on the flat buffer
                        (the Pallas kernel's bit-identical jnp oracle) vs
                        per-leaf quantize + matmul + EF;
  * FL round:           a full DSGD round (Q=4) with flat state threading
                        (make_fl_round(engine=FlatEngine(...))) vs tree
                        state;
  * fused round:        the round megakernel's comm step (ONE fused
                        update+quantize+mix+EF call; two wires for DSGT)
                        vs the pre-megakernel update-then-mix flat path
                        (the update as one jit, then one compressed-gossip
                        jit per wire, compression state threaded through
                        Python at the driver level -- the only way to run
                        a compressed comm round before the megakernel);
  * top-k wire:         the fused round with top-k payload sparsification
                        (k columns per scale chunk inside the kernel, EF
                        absorbing the truncation) vs the dense-int8 wire:
                        per-round wire bytes (values + positions + scales
                        accounting, packing.flat_wire_bytes) and step
                        time;
  * round schedule:     sequential vs PIPELINED full rounds on the fused
                        engine (measured CPU columns + the overlap model
                        that prices the collective-in-flight window an
                        async backend exploits), on the many-leaf state
                        and on a comm-bound single-big-leaf state;
  * compact wire:       the truly sparse top-k receive path (dense int8
                        dequant vs compact scatter-accumulate) and the
                        realized collective operand bytes;
  * bf16 storage:       fp32 vs bf16 flat-buffer storage through the
                        dense W mix (fp32 accumulation on both sides):
                        the halved buffer bytes are the HBM story;
  * bounded staleness:  depth-k rounds (k in {1, 2, 4}) on the fused
                        engine: ring state grows with k, the guarded
                        wire-byte columns prove the collective operand
                        bytes do NOT;
  * node program:       the fault-injection gate's price (per-node
                        uptime hash + masked scan iterations vs the
                        homogeneous lockstep round, one compilation
                        both sides);
  * fused bf16:         full fused rounds with bf16 round STATE
                        (storage_dtype) vs fp32 -- the int8 wire and
                        fp32 EF state are untouched, so the guarded
                        wire columns are equal by construction;
  * two-axis round:     the sharded_fused round on a real
                        (gossip_node, model_shard) host-device mesh,
                        one child process per (nodes, shards) cell
                        (benchmarks/two_axis.py): guarded per-shard
                        wire bytes + step time vs nodes x shards.

``tools/bench_guard.py`` diffs a fresh JSON against the committed
baselines (BENCH_gossip.json full, benchmarks/BENCH_gossip_smoke.json
smoke) in CI: wire bytes at 25% tolerance (deterministic), interleaved
speedup RATIOS with slack, absolute latencies and modeled columns
unguarded.

Methodology (honest measurement on a noisy shared CPU): the first three
rows run ROUNDS consecutive rounds inside ONE jitted lax.scan -- the
steady-state per-round cost of the computation graph itself, with
per-call dispatch amortized away, exactly how a training loop consumes
the engine. The fused-round row CANNOT use that harness for its baseline:
the pre-megakernel path is forced through Python between its stages
(that is precisely what the megakernel removes), so both sides of that
row are timed as per-round dispatch loops with donated buffers (how a
training loop consumes state), gradients precomputed since the grad
evaluation is identical on both sides. All variants are timed INTERLEAVED
over several trials and the median is reported, so slow-container drift
hits both sides equally. The Pallas kernels run in interpret mode
(Python) on CPU, so fused paths are timed via their jnp oracles; the
kernels' additional TPU win (no materialized h/payload/dq/recon HBM
round-trips) is a roofline argument, not a CPU wall-time one.

Usage: PYTHONPATH=src python benchmarks/gossip_bench.py [--out BENCH_gossip.json]
       PYTHONPATH=src python benchmarks/gossip_bench.py --smoke   # tiny CI shapes
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    init_compression_state,
    init_flat_compression_state,
    make_compressed_dense_gossip_per_leaf,
    make_compressed_flat_gossip,
)
from repro.core.engine import FlatEngine, FusedEngine
from repro.core.fl import FLConfig, init_fl_state, make_fl_round
from repro.core.mixing import (
    make_dense_flat_mix,
    make_dense_gossip,
    make_dense_gossip_per_leaf,
)
from repro.core.packing import compact_pos_dtype, flat_wire_bytes, pack
from repro.core.schedules import constant
from repro.core.topology import mixing_matrix

N_NODES = 64
N_LEAVES = 192
SCALE_CHUNK = 512
TOPK = 64  # top-k row: 64 of 512 columns per chunk on the wire
ROUNDS = 50
TRIALS = 9


def make_state(n_nodes: int = N_NODES, n_leaves: int = N_LEAVES) -> Dict:
    """Synthetic many-leaf node-stacked state: mixed ranks, mostly small
    leaves (the shape profile of a real parameter pytree)."""
    rng = np.random.default_rng(0)
    tree = {}
    for i in range(n_leaves):
        shape = [(n_nodes, 16), (n_nodes, 8), (n_nodes, 4, 8), (n_nodes, 8)][i % 4]
        tree[f"leaf_{i:03d}"] = jnp.asarray(rng.normal(size=shape), jnp.float32)
    return tree


def _scan_runner(step: Callable, rounds: int) -> Callable:
    """jit(scan) of `rounds` applications of a (carry -> carry) step."""

    @jax.jit
    def run(carry):
        return jax.lax.scan(lambda c, _: (step(c), None), carry, None, length=rounds)[0]

    return run


def time_interleaved(variants: Dict[str, tuple], rounds: int = None,
                     trials: int = None) -> Dict[str, float]:
    """Median per-round us for {name: (step_fn, init_carry)}, variants
    interleaved within each trial so container noise hits all equally.
    ``rounds``/``trials`` default to the module knobs (resolved at call
    time so --smoke can shrink them)."""
    rounds = ROUNDS if rounds is None else rounds
    trials = TRIALS if trials is None else trials
    runners = {k: (_scan_runner(fn, rounds), init) for k, (fn, init) in variants.items()}
    for run, init in runners.values():  # compile + warm
        jax.block_until_ready(run(init))
    samples = {k: [] for k in runners}
    for _ in range(trials):
        for k, (run, init) in runners.items():
            t0 = time.perf_counter()
            jax.block_until_ready(run(init))
            samples[k].append((time.perf_counter() - t0) / rounds * 1e6)
    return {k: float(np.median(v)) for k, v in samples.items()}


def bench_dense(tree, w) -> Dict:
    flat_buf, layout = pack(tree)
    us = time_interleaved({
        "per_leaf": (make_dense_gossip_per_leaf(w), tree),
        "flat": (make_dense_flat_mix(w), flat_buf),
    })
    return {
        "name": "dense_gossip",
        "n_nodes": flat_buf.shape[0],
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "total_params": layout.used,
        "us_per_leaf": us["per_leaf"],
        "us_flat": us["flat"],
        "speedup_flat": us["per_leaf"] / us["flat"],
    }


def bench_compressed(tree, w) -> Dict:
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    g_leaf = make_compressed_dense_gossip_per_leaf(w)
    g_flat = make_compressed_flat_gossip(w, scale_chunk=SCALE_CHUNK)

    def step_leaf(carry):
        return g_leaf(*carry)

    def step_flat(carry):
        return g_flat(*carry)

    us = time_interleaved({
        "per_leaf": (step_leaf, (tree, init_compression_state(tree))),
        "flat": (step_flat, (flat_buf, init_flat_compression_state(flat_buf))),
    })
    return {
        "name": "compressed_gossip",
        "n_nodes": flat_buf.shape[0],
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "total_params": layout.total,
        "us_per_leaf": us["per_leaf"],
        "us_flat": us["flat"],
        "speedup_flat": us["per_leaf"] / us["flat"],
        "wire_bytes_per_neighbor": flat_wire_bytes(layout, 1, SCALE_CHUNK),
    }


def bench_fl_round(tree, w, q: int = 4) -> Dict:
    n_nodes = w.shape[0]

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n_nodes), jnp.float32)}
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n_nodes)
    sched = constant(0.01)

    rf_tree = make_fl_round(loss_fn, make_dense_gossip(w), sched, cfg)
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    flat_engine = FlatEngine(make_dense_flat_mix(w), layout)
    rf_flat = make_fl_round(loss_fn, None, sched, cfg, engine=flat_engine)

    us = time_interleaved({
        "tree": (lambda st: rf_tree(st, batches)[0], init_fl_state(cfg, tree)),
        "flat": (lambda st: rf_flat(st, batches)[0], init_fl_state(cfg, flat_buf)),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": f"fl_round_dsgd_q{q}",
        "n_nodes": n_nodes,
        "n_leaves": len(jax.tree_util.tree_leaves(tree)),
        "us_tree_state": us["tree"],
        "us_flat_state": us["flat"],
        "speedup_flat": us["tree"] / us["flat"],
        "note": "the flat round re-materializes the tree view inside the "
                "per-node loss every local step (unpack + grad pack), which "
                "XLA CPU lowers to real concats; on TPU these fuse. The "
                "gossip/update/metric steps themselves are the dense_gossip "
                "row's flat path.",
    }


def bench_fused_round(tree, w, algorithm: str, rounds: int = 200,
                      trials: int = 9) -> Dict:
    """Round-megakernel comm step (one fused call) vs the pre-megakernel
    update-then-mix flat path (update jit + one compressed-gossip jit per
    wire, state threaded through Python). Both sides: donated buffers,
    per-round dispatch, precomputed flat gradients (identical grad work on
    both sides is excluded so the row measures the fused machinery)."""
    from repro.kernels.gossip.ref import fused_round_gt_ref, fused_round_ref

    from repro.core.mixing import _split_w

    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    w_self, w_off = _split_w(w)
    alpha = jnp.float32(0.01)
    rng = np.random.default_rng(1)
    g = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    gp = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    tr = jnp.asarray(0.3 * rng.normal(size=(n, t)), jnp.float32)
    zeros = lambda: jnp.zeros((n, t), jnp.float32)

    gfn = make_compressed_flat_gossip(w, scale_chunk=SCALE_CHUNK)
    gossip = jax.jit(lambda h, c: gfn(h, c), donate_argnums=(0, 1))

    if algorithm == "dsgd":
        fused = jax.jit(
            lambda x, r, s: fused_round_ref(
                x, g, r, s, w_off, w_self, alpha, scale_chunk=SCALE_CHUNK
            ),
            donate_argnums=(0, 1, 2),
        )
        upd = jax.jit(lambda x: x - alpha * g, donate_argnums=(0,))

        def run_fused(rounds):
            x, r, s = flat_buf + 0, zeros(), zeros()
            for _ in range(rounds):
                x, r, s, _ = fused(x, r, s)
            jax.block_until_ready(x)

        def run_unfused(rounds):
            x, c = flat_buf + 0, {"recon": zeros(), "residual": zeros()}
            for _ in range(rounds):
                h = upd(x)
                x, c = gossip(h, c)
            jax.block_until_ready(x)

        dispatches = 2
    else:
        fused = jax.jit(
            lambda x, tk, rx, sx, rt, st: fused_round_gt_ref(
                x, tk, g, gp, rx, sx, rt, st, w_off, w_self, alpha,
                scale_chunk=SCALE_CHUNK,
            ),
            donate_argnums=(0, 1, 2, 3, 4, 5),
        )
        upd = jax.jit(
            lambda x, tk: (tk + g - gp, x - alpha * (tk + g - gp)),
            donate_argnums=(0, 1),
        )

        def run_fused(rounds):
            x, tk = flat_buf + 0, tr + 0
            rx, sx, rt, st = zeros(), zeros(), zeros(), zeros()
            for _ in range(rounds):
                x, tk, rx, sx, rt, st, _, _ = fused(x, tk, rx, sx, rt, st)
            jax.block_until_ready(x)

        def run_unfused(rounds):
            x, tk = flat_buf + 0, tr + 0
            cx = {"recon": zeros(), "residual": zeros()}
            ct = {"recon": zeros(), "residual": zeros()}
            for _ in range(rounds):
                th, h = upd(x, tk)
                x, cx = gossip(h, cx)
                tk, ct = gossip(th, ct)
            jax.block_until_ready(x)

        dispatches = 3

    run_fused(10), run_unfused(10)  # compile + warm
    samples = {"fused": [], "update_then_mix": []}
    for _ in range(trials):
        for name, fn in (("fused", run_fused), ("update_then_mix", run_unfused)):
            t0 = time.perf_counter()
            fn(rounds)
            samples[name].append((time.perf_counter() - t0) / rounds * 1e6)
    us = {k: float(np.median(v)) for k, v in samples.items()}
    wires = 2 if algorithm == "dsgt" else 1
    return {
        "name": f"fused_round_{algorithm}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "us_fused": us["fused"],
        "us_update_then_mix": us["update_then_mix"],
        "speedup_fused": us["update_then_mix"] / us["fused"],
        "dispatches_fused": 1,
        "dispatches_update_then_mix": dispatches,
        "wire_bytes_per_neighbor": wires * flat_wire_bytes(layout, 1, SCALE_CHUNK),
        "note": "comm-step machinery only (grad eval identical on both "
                "sides); per-round dispatch with donated buffers -- the "
                "pre-megakernel path is forced through Python between its "
                "stages, which is exactly what the megakernel removes. "
                "jnp-oracle timing on CPU; the Pallas kernel's VMEM win is "
                "a TPU roofline argument.",
    }




def bench_topk_wire(tree, w, algorithm: str, topk: int = TOPK,
                    rounds: int = 200, trials: int = 9) -> Dict:
    """Top-k sparsified wire vs the dense-int8 wire, same fused round
    machinery (jnp oracle on CPU, donated-buffer dispatch loop). Reports
    measured step time and the per-round wire bytes of each
    (values + position encoding + scales for top-k; see
    packing.flat_wire_bytes). The CPU step-time delta is the in-kernel
    sort cost; the wire-byte column is the point -- the payload drops
    below the int8 floor while EF keeps the mixing contraction
    (tests/test_topk_property.py property-tests consensus under top-k)."""
    from repro.kernels.gossip.ref import fused_round_gt_ref, fused_round_ref

    from repro.core.mixing import _split_w

    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    w_self, w_off = _split_w(w)
    alpha = jnp.float32(0.01)
    rng = np.random.default_rng(3)
    g = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    gp = jnp.asarray(0.5 * rng.normal(size=(n, t)), jnp.float32)
    tr = jnp.asarray(0.3 * rng.normal(size=(n, t)), jnp.float32)
    zeros = lambda: jnp.zeros((n, t), jnp.float32)

    def make_runner(k):
        if algorithm == "dsgd":
            step = jax.jit(
                lambda x, r, s: fused_round_ref(
                    x, g, r, s, w_off, w_self, alpha, scale_chunk=SCALE_CHUNK,
                    topk=k,
                ),
                donate_argnums=(0, 1, 2),
            )

            def run(nr):
                x, r, s = flat_buf + 0, zeros(), zeros()
                for _ in range(nr):
                    x, r, s, _ = step(x, r, s)
                jax.block_until_ready(x)
        else:
            step = jax.jit(
                lambda x, tk, rx, sx, rt, st: fused_round_gt_ref(
                    x, tk, g, gp, rx, sx, rt, st, w_off, w_self, alpha,
                    scale_chunk=SCALE_CHUNK, topk=k,
                ),
                donate_argnums=(0, 1, 2, 3, 4, 5),
            )

            def run(nr):
                x, tk = flat_buf + 0, tr + 0
                rx, sx, rt, st = zeros(), zeros(), zeros(), zeros()
                for _ in range(nr):
                    x, tk, rx, sx, rt, st, _, _ = step(x, tk, rx, sx, rt, st)
                jax.block_until_ready(x)
        return run

    runners = {"int8": make_runner(None), "topk": make_runner(topk)}
    for r in runners.values():
        r(10)  # compile + warm
    samples = {k: [] for k in runners}
    for _ in range(trials):
        for name, fn in runners.items():
            t0 = time.perf_counter()
            fn(rounds)
            samples[name].append((time.perf_counter() - t0) / rounds * 1e6)
    us = {k: float(np.median(v)) for k, v in samples.items()}
    wires = 2 if algorithm == "dsgt" else 1
    int8_bytes = wires * flat_wire_bytes(layout, 1, SCALE_CHUNK)
    topk_bytes = wires * flat_wire_bytes(layout, 1, SCALE_CHUNK, topk)
    return {
        "name": f"topk_wire_{algorithm}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "topk": topk,
        "us_int8": us["int8"],
        "us_topk": us["topk"],
        "wire_bytes_per_neighbor_int8": int8_bytes,
        "wire_bytes_per_neighbor_topk": topk_bytes,
        "wire_reduction_vs_int8": int8_bytes / topk_bytes,
        "note": "same fused round, payload masked to the k largest "
                "columns per scale chunk inside the kernel; wire bytes = "
                "k int8 values + min(2k, chunk/8) position bytes + 4 B "
                "scale per chunk. EF absorbs the truncation. jnp-oracle "
                "timing on CPU (the sort is in-tile on TPU).",
    }

def make_big_state(n_nodes: int = N_NODES, total: int = 16384) -> Dict:
    """ONE big leaf: the comm-bound shape profile (mixing >> grad eval)
    where the pipelined schedule's overlap is the round's lever -- the
    regime a bandwidth-bound deployment lives in."""
    rng = np.random.default_rng(1)
    return {"w": jnp.asarray(rng.normal(size=(n_nodes, total)), jnp.float32)}


def bench_schedule(tree, w, algorithm: str = "dsgd", q: int = 4,
                   label: str = "") -> Dict:
    """Sequential vs PIPELINED round schedule on the fused engine, full
    rounds (grad eval + Q-1 local-step scan + comm step) in the scan
    harness.

    What the pipelined schedule buys is OVERLAP: the collective/neighbor
    term it consumes depends on nothing the local-step scan computes
    (asserted on the jaxpr in tests/test_schedule.py), so an
    async-collective backend hides min(t_collective, t_local_steps) of
    wall clock per round. XLA:CPU runs collectives synchronously in
    process, so the MEASURED columns here are near parity -- the honest
    CPU numbers -- and the `us_pipelined_overlap_model` column prices the
    schedule on an overlapping backend: us_pipelined minus the hideable
    min(us_mix_term, us_local_steps), which is the wall clock a
    latency-hiding scheduler converges to. At Q >= 4 the local steps are
    long enough to hide the whole mix term and the model sits strictly
    below sequential."""
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    cfg1 = FLConfig(algorithm=algorithm, q=1, n_nodes=n)
    sched = constant(0.01)

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n), jnp.float32)}
    batches1 = {"t": jnp.zeros((1, n), jnp.float32)}

    def make(rs, c):
        eng, f0 = FusedEngine.simulated(w, tree, scale_chunk=SCALE_CHUNK,
                                        impl="jnp", round_schedule=rs)
        rf = make_fl_round(loss_fn, None, sched, c, engine=eng)
        return rf, init_fl_state(c, f0, engine=eng)

    rf_seq, st_seq = make("sequential", cfg)
    rf_pipe, st_pipe = make("pipelined", cfg)
    rf_seq1, st_seq1 = make("sequential", cfg1)

    # the hideable neighbor-mix term, measured standalone (same shapes)
    w_off = jnp.asarray(w - np.diag(np.diag(w)), jnp.float32)
    recon0 = jnp.asarray(np.random.default_rng(0).normal(size=(n, t)),
                         jnp.float32)

    us = time_interleaved({
        "seq": (lambda st: rf_seq(st, batches)[0], st_seq),
        "pipe": (lambda st: rf_pipe(st, batches)[0], st_pipe),
        "seq_q1": (lambda st: rf_seq1(st, batches1)[0], st_seq1),
        "mix_term": (lambda r: w_off @ r, recon0),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    us_local = max(us["seq"] - us["seq_q1"], 0.0)
    hidden = min(us["mix_term"], us_local)
    return {
        "name": f"pipelined_round_{algorithm}_q{q}{label}",
        "n_nodes": n,
        "total_params": t,
        "q": q,
        "us_sequential": us["seq"],
        "us_pipelined_measured": us["pipe"],
        "us_local_steps": us_local,
        "us_mix_term": us["mix_term"],
        "us_pipelined_overlap_model": us["pipe"] - hidden,
        "overlap_model_speedup_vs_sequential": us["seq"] / (us["pipe"] - hidden),
        "note": "measured columns are XLA:CPU (synchronous in-process "
                "collectives -- expect parity); the overlap model subtracts "
                "the hideable min(mix term, local steps), i.e. the round "
                "time once an async backend schedules the collective issued "
                "BEFORE the local-step scan (jaxpr ordering asserted in "
                "tests/test_schedule.py). Numerics are one-round-stale "
                "mixing; quality cost quantified in "
                "experiments/staleness_ehr.json.",
    }


def bench_staleness_depth(tree, w, algorithm: str = "dsgt", q: int = 4) -> Dict:
    """Depth-k bounded staleness vs the depth-1 pipeline: full fused
    rounds at k in {1, 2, 4}. The k in-flight payloads live in the
    engine's RING STATE (difference-coded reconstructions held per
    node), NOT on the wire: per-round collective operand bytes are
    IDENTICAL across depths -- the guarded wire_bytes columns pin that
    down (a regression that shipped the ring would multiply them by k).
    The measured step-time delta is the ring rotate + stale-slot
    subtraction, O(n * params) adds against the round's matmul."""
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = constant(0.01)

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n), jnp.float32)}

    def make(rs):
        eng, f0 = FusedEngine.simulated(w, tree, scale_chunk=SCALE_CHUNK,
                                        impl="jnp", round_schedule=rs)
        rf = make_fl_round(loss_fn, None, sched, cfg, engine=eng)
        ring = sum(
            int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
            for key, s in (eng.comm_state_sds(cfg) or {}).items()
            if key.startswith("wire_")
        )
        return eng, rf, init_fl_state(cfg, f0, engine=eng), ring

    eng1, rf1, st1, ring1 = make("pipelined")
    eng2, rf2, st2, ring2 = make("bounded_staleness:k=2")
    eng4, rf4, st4, ring4 = make("bounded_staleness:k=4")
    us = time_interleaved({
        "k1": (lambda st: rf1(st, batches)[0], st1),
        "k2": (lambda st: rf2(st, batches)[0], st2),
        "k4": (lambda st: rf4(st, batches)[0], st4),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": f"bounded_staleness_round_{algorithm}_q{q}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "q": q,
        "us_pipelined_k1": us["k1"],
        "us_bounded_k2": us["k2"],
        "us_bounded_k4": us["k4"],
        "wire_bytes_per_round_k1": eng1.wire_bytes(cfg),
        "wire_bytes_per_round_k2": eng2.wire_bytes(cfg),
        "wire_bytes_per_round_k4": eng4.wire_bytes(cfg),
        "ring_state_bytes_k1": ring1,
        "ring_state_bytes_k2": ring2,
        "ring_state_bytes_k4": ring4,
        "note": "guarded wire_bytes_per_round_k* columns are EQUAL by "
                "construction: depth-k keeps k payloads in flight as "
                "node-local ring state (ring_state_bytes_k* grows with "
                "k) while each round still ships exactly one payload "
                "per wire. Equality across k is also asserted in "
                "tests/test_bounded_staleness.py; quality-vs-depth is "
                "experiments/straggler_ehr.json.",
    }


def bench_compact_wire(tree, w, topk: int = None, degree: int = 4) -> Dict:
    """The truly sparse top-k wire's RECEIVE path: dense int8 dequant of
    (nodes, total) vs scatter-accumulate of the compact buffers under
    BOTH index encodings (explicit positions / presence bitmap) -- per
    neighbor per round -- plus the wire-byte columns that are the point
    of the encoding (the collective operand bytes of the CHEAPER
    encoding, not a model; asserted in tests/test_schedule.py and
    tests/test_dynamics.py). At the full shapes (k=64, chunk=512) the
    bitmap restores the modeled 3.9x reduction over dense int8 that
    explicit positions capped at 2.6x."""
    from repro.kernels.gossip.ref import (
        _quantize_ef_compact_chunks,
        compact_to_bitmap,
        scatter_bitmap_dq,
        scatter_compact_dq,
    )

    topk = TOPK if topk is None else topk
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    c = t // SCALE_CHUNK
    rng = np.random.default_rng(5)
    payload = jnp.asarray(rng.normal(size=(n, t)), jnp.float32)
    q_c, pos_c, sc_c, _ = _quantize_ef_compact_chunks(payload, SCALE_CHUNK, topk)
    q_c = q_c.astype(jnp.int8)
    pos_c = pos_c.astype(compact_pos_dtype(SCALE_CHUNK))
    vals_b, bits_b = compact_to_bitmap(q_c, pos_c, SCALE_CHUNK, topk)
    q_d = jnp.clip(jnp.round(payload), -127, 127).astype(jnp.int8)
    sc_d = jnp.abs(payload).reshape(n, c, SCALE_CHUNK).max(-1) / 127.0

    def dense_recv(acc):
        q3 = q_d.astype(jnp.float32).reshape(n, c, SCALE_CHUNK)
        return acc + 0.25 * (q3 * sc_d[:, :, None]).reshape(n, t)

    def compact_recv(acc):
        return acc + 0.25 * scatter_compact_dq(q_c, pos_c, sc_c, SCALE_CHUNK, t)

    def bitmap_recv(acc):
        return acc + 0.25 * scatter_bitmap_dq(vals_b, bits_b, sc_c,
                                              SCALE_CHUNK, t)

    zeros = jnp.zeros((n, t), jnp.float32)
    us = time_interleaved({
        "dense": (dense_recv, zeros),
        "compact": (compact_recv, zeros),
        "bitmap": (bitmap_recv, zeros),
    }, rounds=min(30, ROUNDS), trials=min(7, TRIALS))
    dense_bytes = flat_wire_bytes(layout, degree, SCALE_CHUNK)
    compact_bytes = flat_wire_bytes(layout, degree, SCALE_CHUNK, topk)
    pos_itemsize = jnp.dtype(compact_pos_dtype(SCALE_CHUNK)).itemsize
    positions_bytes = degree * c * min(
        topk + topk * pos_itemsize + 4, SCALE_CHUNK + 4
    )
    bitmap_bytes = degree * c * min(
        topk + SCALE_CHUNK // 8 + 4, SCALE_CHUNK + 4
    )
    return {
        "name": "compact_wire_receive",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "topk": topk,
        "degree": degree,
        "us_dense_dequant": us["dense"],
        "us_compact_scatter": us["compact"],
        "us_bitmap_scatter": us["bitmap"],
        "speedup_compact_recv": us["dense"] / us["compact"],
        "wire_bytes_dense_int8": dense_bytes,
        "wire_bytes_compact": compact_bytes,
        "wire_bytes_if_positions": positions_bytes,
        "wire_bytes_if_bitmap": bitmap_bytes,
        "wire_encoding": "bitmap" if bitmap_bytes < positions_bytes
                         else "positions",
        "wire_reduction_compact": dense_bytes / compact_bytes,
        "note": "per-neighbor receive work: the dense wire dequantizes "
                "every column, the compact wire rebuilds only k per "
                "chunk (positions: scatter-add; bitmap: unpack + "
                "prefix-sum gather). wire_bytes_compact is the CHEAPER "
                "of the two index encodings per (k, chunk) -- the "
                "collective's actual operand sizes, auto-picked by the "
                "sharded engine (engine.wire_encoding).",
    }


def bench_churn(tree, w, spec: str = "node_churn:p_down=0.25,mean_downtime=5,seed=0",
                q: int = 4) -> Dict:
    """Dynamic topology's compute cost: the fused FD-DSGD round with a
    static compile-time W vs the SAME round under a TopologyProgram
    (traced per-round W derived from the comm counters, gated mixing).
    ONE compiled function on both sides -- the delta is the gate
    arithmetic (a hash over (n, n) + masking), which is O(n^2) against
    the round's O(n * params) work. Wire bytes are UNCHANGED under churn
    (the difference-coded wire still crosses every round; only the mix
    is gated), which the guarded wire column pins down."""
    from repro.core.engine import FusedEngine

    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = constant(0.01)

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n), jnp.float32)}

    def make(program):
        eng, f0 = FusedEngine.simulated(w, tree, scale_chunk=SCALE_CHUNK,
                                        impl="jnp", topology_program=program)
        rf = make_fl_round(loss_fn, None, sched, cfg, engine=eng)
        return eng, rf, init_fl_state(cfg, f0, engine=eng)

    eng_s, rf_s, st_s = make(None)
    eng_d, rf_d, st_d = make(spec)
    us = time_interleaved({
        "static": (lambda st: rf_s(st, batches)[0], st_s),
        "dynamic": (lambda st: rf_d(st, batches)[0], st_d),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": f"churn_round_dsgd_q{q}",
        "n_nodes": n,
        "total_params": t,
        "q": q,
        "program": eng_d.topology_program.spec(),
        "us_static": us["static"],
        "us_dynamic": us["dynamic"],
        "dynamic_overhead_ratio": us["dynamic"] / us["static"],
        "wire_bytes_per_round": eng_d.wire_bytes(cfg),
        "wire_bytes_static": eng_s.wire_bytes(cfg),
        "note": "same fused round, same wire, same single compilation; "
                "the dynamic side derives W_r from the comm counters "
                "each round (counter-based hash gate + diagonal fold) "
                "and feeds it to the kernel as a traced operand. "
                "Quality-vs-downtime is experiments/churn_ehr.json; "
                "this row prices the mechanism.",
    }


def bench_node_program(tree, w,
                       spec: str = "stragglers:frac=0.25,rate=0.5,drop=1,seed=0",
                       q: int = 4) -> Dict:
    """Node heterogeneity's compute cost: the fused FD-DSGD round with
    lockstep homogeneous nodes vs the SAME round under a NodeProgram
    (per-round uptime gate composed into W_r, masked local-step scan
    iterations). ONE compiled function on both sides -- the delta is the
    per-node hash + the (q-1, n) step mask multiply inside the scan,
    O(q * n + n^2) against the round's O(n * params) work. The guarded
    wire column pins down that fault injection never changes what
    crosses the wire (dropped payloads are ignored at the RECEIVER by
    the drop-renormalized W_r; the difference-coded stream still flows
    so reconstructions stay in sync)."""
    flat_buf, layout = pack(tree, pad_to=SCALE_CHUNK)
    n, t = flat_buf.shape
    cfg = FLConfig(algorithm="dsgd", q=q, n_nodes=n)
    sched = constant(0.01)

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n), jnp.float32)}

    def make(program):
        eng, f0 = FusedEngine.simulated(w, tree, scale_chunk=SCALE_CHUNK,
                                        impl="jnp", node_program=program)
        rf = make_fl_round(loss_fn, None, sched, cfg, engine=eng)
        return eng, rf, init_fl_state(cfg, f0, engine=eng)

    eng_h, rf_h, st_h = make(None)
    eng_f, rf_f, st_f = make(spec)
    us = time_interleaved({
        "homogeneous": (lambda st: rf_h(st, batches)[0], st_h),
        "faulty": (lambda st: rf_f(st, batches)[0], st_f),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": f"node_program_round_dsgd_q{q}",
        "n_nodes": n,
        "total_params": t,
        "q": q,
        "program": eng_f.node_program.spec(),
        "us_homogeneous": us["homogeneous"],
        "us_faulty": us["faulty"],
        "fault_overhead_ratio": us["faulty"] / us["homogeneous"],
        "wire_bytes_per_round": eng_f.wire_bytes(cfg),
        "wire_bytes_homogeneous": eng_h.wire_bytes(cfg),
        "note": "same fused round, same wire, same single compilation; "
                "the faulty side derives per-node uptime + step masks "
                "from the round counter each round and folds dropped "
                "mixing weight into the self-loops. Quality-vs-faults "
                "is experiments/straggler_ehr.json; this row prices the "
                "mechanism.",
    }


def bench_bf16_storage(tree, w) -> Dict:
    """bf16 flat-buffer STORAGE vs fp32 (the flat engine's storage_dtype
    knob): one dense W mix per round on each. The accumulation is fp32 on
    both sides (make_dense_flat_mix); what changes is the bytes every
    buffer-wide op moves -- halved, the HBM-traffic column. On CPU the
    matmul converts bf16 inputs up to fp32, so measured time is
    conversion-bound; on TPU the mix is HBM-bound and the byte column is
    the wall-clock story."""
    flat32, layout = pack(tree, pad_to=SCALE_CHUNK)
    flat16 = flat32.astype(jnp.bfloat16)
    n, t = flat32.shape
    mix = make_dense_flat_mix(w)
    us = time_interleaved({
        "fp32": (mix, flat32),
        "bf16": (mix, flat16),
    }, rounds=min(30, ROUNDS), trials=min(7, TRIALS))
    return {
        "name": "bf16_flat_storage",
        "n_nodes": n,
        "total_params": t,
        "us_fp32": us["fp32"],
        "us_bf16": us["bf16"],
        "buffer_bytes_fp32": 4 * n * t,
        "buffer_bytes_bf16": 2 * n * t,
        "hbm_traffic_reduction": 2.0,
        "note": "storage_dtype='bfloat16' on the flat engine; mix "
                "accumulates fp32 and stores back bf16 (equivalence at "
                "relaxed tolerance in tests/test_schedule.py). The byte "
                "columns are the HBM story; CPU wall time includes "
                "bf16<->fp32 conversion the TPU does for free in the MXU.",
    }


def bench_fused_bf16_round(tree, w, algorithm: str = "dsgt", q: int = 4) -> Dict:
    """bf16 STORAGE through the full fused round (params/tracker/prev_grad
    kept bf16; the wire stays int8 and the EF recon/residual state stays
    fp32): fp32 vs bf16 storage_dtype on FusedEngine, full rounds in the
    scan harness. The kernel body runs fp32 on both sides -- the casts
    sit at the storage boundary -- so the wire-byte column is IDENTICAL
    and guarded; the buffer-byte columns are the halved-HBM story
    (equivalence at relaxed tolerance in tests/test_schedule.py)."""
    n = w.shape[0]
    cfg = FLConfig(algorithm=algorithm, q=q, n_nodes=n)
    sched = constant(0.01)

    def loss_fn(params, batch):
        sq = 0.0
        for leaf in jax.tree_util.tree_leaves(params):
            sq = sq + jnp.sum((leaf.astype(jnp.float32) - batch["t"]) ** 2) / leaf.size
        return sq

    batches = {"t": jnp.zeros((q, n), jnp.float32)}

    def make(storage):
        eng, f0 = FusedEngine.simulated(w, tree, scale_chunk=SCALE_CHUNK,
                                        impl="jnp", storage_dtype=storage)
        rf = make_fl_round(loss_fn, None, sched, cfg, engine=eng)
        return eng, rf, init_fl_state(cfg, f0, engine=eng)

    eng32, rf32, st32 = make(None)
    eng16, rf16, st16 = make(jnp.bfloat16)
    us = time_interleaved({
        "fp32": (lambda st: rf32(st, batches)[0], st32),
        "bf16": (lambda st: rf16(st, batches)[0], st16),
    }, rounds=min(20, ROUNDS), trials=min(7, TRIALS))
    t = eng32.layout.total
    state_bufs = 3 if algorithm == "dsgt" else 1  # params (+tracker+prev_grad)
    return {
        "name": f"fused_bf16_storage_{algorithm}",
        "n_nodes": n,
        "total_params": t,
        "scale_chunk": SCALE_CHUNK,
        "q": q,
        "us_fp32": us["fp32"],
        "us_bf16": us["bf16"],
        "state_bytes_fp32": 4 * state_bufs * n * t,
        "state_bytes_bf16": 2 * state_bufs * n * t,
        "wire_bytes_per_round": eng16.wire_bytes(cfg),
        "wire_bytes_per_round_fp32": eng32.wire_bytes(cfg),
        "note": "storage_dtype='bfloat16' on the fused engine: the stored "
                "round state halves while the int8 wire and the fp32 EF "
                "recon/residual are untouched -- the two guarded "
                "wire_bytes columns are equal by construction. CPU wall "
                "time includes the boundary casts the TPU MXU does for "
                "free.",
    }


def main() -> List[Dict]:
    global ROUNDS, TRIALS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_gossip.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + few rounds: the CI smoke that "
                         "exercises every row (numbers are NOT "
                         "representative; the committed BENCH_gossip.json "
                         "is the full run)")
    args = ap.parse_args()

    if args.smoke:
        ROUNDS, TRIALS = 5, 3
        tree = make_state(n_nodes=8, n_leaves=12)
        big_state = make_big_state(n_nodes=8, total=1024)
        w = mixing_matrix("torus:4x2", 8)
        fused_rounds, fused_trials = 10, 3
    else:
        tree = make_state()
        big_state = make_big_state()
        w = mixing_matrix("torus:8x8", N_NODES)
        fused_rounds, fused_trials = 200, 9

    rows = [
        bench_dense(tree, w),
        bench_compressed(tree, w),
        bench_fl_round(tree, w),
        bench_fused_round(tree, w, "dsgd", fused_rounds, fused_trials),
        bench_fused_round(tree, w, "dsgt", fused_rounds, fused_trials),
        # fewer samples: the row's point is the wire-byte column; the CPU
        # step time only prices the jnp-oracle sort (in-tile on TPU)
        bench_topk_wire(tree, w, "dsgd", rounds=min(fused_rounds, 40),
                        trials=min(fused_trials, 5)),
        bench_topk_wire(tree, w, "dsgt", rounds=min(fused_rounds, 40),
                        trials=min(fused_trials, 5)),
        bench_schedule(tree, w, "dsgd", q=4),
        bench_schedule(tree, w, "dsgt", q=4),
        # comm-bound regime (one big leaf, mixing >> grad eval): where the
        # pipeline's overlap is the round's lever
        bench_schedule(big_state, w, "dsgd", q=4, label="_commbound"),
        # depth-k bounded staleness: ring state grows with k, the WIRE
        # does not (guarded wire_bytes_per_round_k* columns are equal)
        bench_staleness_depth(tree, w, "dsgt", q=4),
        bench_compact_wire(tree, w, topk=4 if args.smoke else None),
        bench_bf16_storage(tree, w),
        # bf16 storage through the FULL fused round (wire stays int8;
        # the guarded wire columns are equal fp32 vs bf16)
        bench_fused_bf16_round(tree, w, "dsgt"),
        # dynamic topology: the traced per-round-W mechanism's price
        # (quality-vs-downtime lives in experiments/churn_ehr.json)
        bench_churn(tree, w),
        # node heterogeneity: the fourth-axis fault gate's price
        # (quality-vs-faults lives in experiments/straggler_ehr.json)
        bench_node_program(tree, w),
    ]
    # two-axis (gossip_node, model_shard) rounds: one child process per
    # (nodes, shards) cell -- XLA locks this process's device count, so
    # the mesh cells cannot run in-process (benchmarks/two_axis.py)
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.two_axis import two_axis_row

    rows.append(two_axis_row(smoke=args.smoke))
    for r in rows:
        extras = {k: v for k, v in r.items() if isinstance(v, float)}
        print(f"  {r['name']:22s} " + "  ".join(f"{k}={v:10.1f}" for k, v in extras.items()))

    record = {
        "bench": "gossip_flat_vs_per_leaf",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "rounds_per_sample": ROUNDS,
        "trials": TRIALS,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
