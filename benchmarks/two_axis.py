"""Two-axis (gossip_node, model_shard) round micro-benchmark helper.

The sharded engine's round runs under shard_map on a real device mesh,
and XLA locks the host device count at first jax initialization -- so a
process that already imported jax (gossip_bench, thm1_speedup) cannot
re-mesh itself. Each (nodes, shards) cell therefore runs in a CHILD
process: ``python -m benchmarks.two_axis --nodes N --shards S ...``
forces ``N * S`` host devices before importing jax, times the full
fused round (jnp oracle; the Pallas kernel is a TPU story) on the
``(data, model)`` mesh, and prints one JSON record. The parent-side
helpers compose those records into BENCH_gossip.json rows:

  * ``wire_bytes_per_shard_*`` -- deterministic per-shard collective
    operand bytes (``packing.flat_wire_bytes_per_shard``); the guarded
    columns. Per-shard bytes x shards == the single-axis wire bytes:
    sharding tiles the payload, it never grows it.
  * ``us_n{N}_s{S}`` -- measured step time vs node-count x shard-count
    (unguarded absolutes; the interleaving protection of the in-process
    rows does not apply across processes).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (nodes, shards) cells: same device budget (8 host devices), the
# shard axis traded against the node axis. The s=1 cell is the
# single-axis reference the equivalence tests pin to 1e-5.
CELLS: Tuple[Tuple[int, int], ...] = ((8, 1), (4, 2), (2, 4))


def run_cell(nodes: int, shards: int, *, total: int = 8192,
             chunk: int = 256, topk: int = 32, algorithm: str = "dsgt",
             q: int = 2, rounds: int = 20, trials: int = 5,
             timeout: int = 1200) -> Dict:
    """Run one (nodes, shards) cell in a child process; return its record."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.two_axis",
           "--nodes", str(nodes), "--shards", str(shards),
           "--total", str(total), "--chunk", str(chunk),
           "--topk", str(topk), "--algorithm", algorithm,
           "--q", str(q), "--rounds", str(rounds), "--trials", str(trials)]
    proc = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                          text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"two_axis cell n={nodes} s={shards} failed:\n"
            + proc.stderr[-4000:]
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def two_axis_row(smoke: bool = False) -> Dict:
    """The BENCH_gossip.json row: one record spanning all cells."""
    if smoke:
        kw = dict(total=1024, chunk=64, topk=8, rounds=5, trials=3)
    else:
        kw = dict(total=8192, chunk=256, topk=32, rounds=20, trials=5)
    row: Dict = {
        "name": "two_axis_round_dsgt",
        "total_params": kw["total"],
        "scale_chunk": kw["chunk"],
        "topk": kw["topk"],
        "q": 2,
        "model_shards": max(s for _, s in CELLS),
        "note": "full sharded_fused DSGT rounds on a (data, model) host-"
                "device mesh, one subprocess per (nodes, shards) cell; "
                "wire_bytes_per_shard_* are the deterministic per-shard "
                "collective operand bytes (guarded) -- per-shard bytes x "
                "shards == the single-axis wire bytes, so sharding tiles "
                "the payload without growing it. us_* absolutes are "
                "cross-process and unguarded.",
    }
    for nodes, shards in CELLS:
        rec = run_cell(nodes, shards, algorithm="dsgt", **kw)
        tag = f"n{nodes}_s{shards}"
        row[f"us_{tag}"] = rec["us_per_round"]
        row[f"wire_bytes_per_shard_{tag}"] = rec["wire_bytes_per_shard"]
        row[f"wire_bytes_per_round_{tag}"] = rec["wire_bytes_per_round"]
        assert abs(rec["wire_bytes_per_shard"] * shards
                   - rec["wire_bytes_per_round"]) < 1e-6, rec
    return row


def _child_main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, required=True)
    ap.add_argument("--shards", type=int, required=True)
    ap.add_argument("--total", type=int, default=8192)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--topk", type=int, default=32)
    ap.add_argument("--algorithm", default="dsgt")
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--trials", type=int, default=5)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.nodes * args.shards} "
        + os.environ.get("XLA_FLAGS", "")
    )
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.core import (
        FLConfig,
        ShardedFusedEngine,
        init_fl_state,
        make_fl_round,
        pack,
    )
    from repro.core.schedules import constant

    n, s = args.nodes, args.shards
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(n, args.total)), jnp.float32)}
    batches = {"t": jnp.asarray(rng.normal(size=(args.q, n)), jnp.float32)}

    def loss(p, batch):
        return jnp.sum((p["w"] - batch["t"]) ** 2) / args.total

    mesh = jax.make_mesh((n, s), ("data", "model"))
    engine = ShardedFusedEngine.from_mesh(
        mesh, ("data",), params, scale_chunk=args.chunk, topk=args.topk,
        impl="jnp", model_axis="model" if s > 1 else None)
    cfg = FLConfig(algorithm=args.algorithm, q=args.q, n_nodes=n)
    flat, _ = pack(params, pad_to=args.chunk * s)
    with mesh:
        rf = jax.jit(make_fl_round(loss, None, constant(0.01), cfg,
                                   engine=engine))
        st = init_fl_state(cfg, jax.device_put(
            flat, NamedSharding(mesh, engine.params_spec())), engine=engine)
        st, _ = rf(st, batches)  # compile + warm
        jax.block_until_ready(st.params)
        samples = []
        for _ in range(args.trials):
            t0 = time.perf_counter()
            for _ in range(args.rounds):
                st, _ = rf(st, batches)
            jax.block_until_ready(st.params)
            samples.append((time.perf_counter() - t0) / args.rounds * 1e6)

    print(json.dumps({
        "nodes": n,
        "shards": int(engine.model_shards),
        "total_params": int(engine.layout.total),
        "shard_width": int(engine.layout.shard_width),
        "us_per_round": float(np.median(samples)),
        "wire_bytes_per_shard": float(engine.wire_bytes_per_shard(cfg)),
        "wire_bytes_per_round": float(engine.wire_bytes(cfg)),
    }))


if __name__ == "__main__":
    _child_main()
