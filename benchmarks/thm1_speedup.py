"""Theorem 1 validation: linear speedup of DSGT in the number of nodes N.

Theorem 1: with alpha^r ~ O(sqrt(N/r)) and Q=1,

  (1/T) sum_r [ ||mean_i grad f_i||^2 + (1/N) sum_i ||theta_i - theta_bar||^2 ]
      <= O(sigma^2 / (N sqrt(T)))

We train DSGT (Q=1) on a synthetic non-IID least-squares problem with
IDENTICAL total data but N in {4, 8, 16} nodes (ring topology), fixed T,
and report the time-averaged stationarity measure. The claim holds if the
measure shrinks ~linearly as N grows.

``--two-axis`` adds the wall-clock companion table: measured step time
vs node-count x shard-count on the two-axis (gossip_node, model_shard)
host-device mesh (one subprocess per cell -- see benchmarks/two_axis.py),
showing how the round time trades when devices move from the node axis
to the model axis at a fixed device budget.
"""

from __future__ import annotations

import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FLConfig, init_fl_state, make_dense_gossip, make_fl_round, mixing_matrix
from repro.core.schedules import theorem1_schedule

D = 24
NOISE = 1.0  # gradient noise sigma


def make_problem(n_nodes: int, seed: int = 0):
    """Per-node linear regression with heterogeneous optima; stochastic
    gradients carry iid noise with variance sigma^2 (Assumption 2)."""
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(n_nodes, D)), jnp.float32)

    def loss(params, batch):
        # batch carries the noise sample (m=1 stochastic gradient)
        return 0.5 * jnp.sum((params["x"] - batch["target"] - batch["noise"]) ** 2)

    return targets, loss


def run_one(n_nodes: int, t_steps: int, seed: int = 0, c: float = 0.05) -> float:
    targets, loss = make_problem(n_nodes, seed)
    cfg = FLConfig(algorithm="dsgt", q=1, n_nodes=n_nodes)
    w = mixing_matrix("ring", n_nodes)
    rf = jax.jit(make_fl_round(loss, make_dense_gossip(w), theorem1_schedule(n_nodes, c), cfg))
    state = init_fl_state(cfg, {"x": jnp.zeros((n_nodes, D))})
    rng = np.random.default_rng(seed + 1)
    measure = 0.0
    for _ in range(t_steps):
        batch = {
            "target": jnp.broadcast_to(targets, (1, n_nodes, D)),
            "noise": jnp.asarray(
                NOISE * rng.normal(size=(1, n_nodes, D)) / np.sqrt(D), jnp.float32
            ),
        }
        state, m = rf(state, batch)
        measure += float(m["grad_norm_sq"]) + float(m["consensus_err"])
    return measure / t_steps


def two_axis_table(smoke: bool = False) -> Dict:
    """Step time vs (nodes, shards) at a fixed 8-device budget."""
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.two_axis import CELLS, run_cell

    kw = (dict(total=1024, chunk=64, topk=8, rounds=5, trials=3) if smoke
          else dict(total=8192, chunk=256, topk=32, rounds=20, trials=5))
    print("\nTwo-axis round time vs node-count x shard-count "
          f"(DSGT, total={kw['total']}, 8 host devices)")
    out = {}
    for nodes, shards in CELLS:
        rec = run_cell(nodes, shards, algorithm="dsgt", **kw)
        out[f"n{nodes}_s{shards}"] = rec
        print(f"  N={nodes:2d} x S={shards:2d}: {rec['us_per_round']:9.1f} "
              f"us/round, {rec['wire_bytes_per_shard']:.0f} wire B/shard "
              f"({rec['wire_bytes_per_round']:.0f} B/round)")
    return out


def main(t_steps: int = 400, seeds: int = 3) -> Dict:
    print("Theorem 1: time-averaged stationarity+consensus vs N (DSGT, Q=1)")
    out = {}
    for n in (4, 8, 16):
        vals = [run_one(n, t_steps, seed=s) for s in range(seeds)]
        out[n] = float(np.mean(vals))
        print(f"  N={n:3d}: measure={out[n]:.5f}")
    r48 = out[4] / out[8]
    r816 = out[8] / out[16]
    print(f"  ratios: N4/N8={r48:.2f}, N8/N16={r816:.2f}  (linear speedup => ~2.0)")
    return {"measure": out, "ratio_4_8": r48, "ratio_8_16": r816}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--t-steps", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--two-axis", action="store_true",
                    help="also time full rounds vs node-count x shard-count "
                         "on the (gossip_node, model_shard) mesh")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the --two-axis cells to seconds-scale")
    args = ap.parse_args()
    res = main(args.t_steps, args.seeds)
    if args.two_axis:
        res["two_axis"] = two_axis_table(smoke=args.smoke)
    with open("experiments/thm1_results.json", "w") as f:
        json.dump(res, f)
