"""Communication-efficiency table: bytes on the wire per training ITERATION.

Restates the paper's communication-round saving in transport bytes for a
real model (tinyllama-1.1b full config): per-node egress bytes per
iteration under each strategy, ring-gossip FD-Q amortization, bf16 wire,
and the all-reduce / star baselines. Cross-checked against the collective
bytes the dry-run parser extracts from the compiled HLO.
"""

from __future__ import annotations

import json
import os
from typing import Dict

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.training.metrics import allreduce_bytes, comm_bytes_per_gossip, param_bytes


def main(arch: str = "tinyllama-1.1b") -> Dict:
    cfg = get_config(arch)
    bundle = build_model(cfg)
    shapes = jax.eval_shape(bundle.init_fn, jax.random.key(0))
    n = 16  # single-pod FL nodes
    p = param_bytes(shapes)
    rows = []

    def row(name, bytes_per_iter):
        rows.append({"strategy": name, "bytes_per_iter_per_node": bytes_per_iter,
                     "ratio_vs_centralized": bytes_per_iter / ar})

    from repro.core.compression import DEFAULT_SCALE_CHUNK
    from repro.core.packing import flat_wire_bytes, pack_layout

    ar = allreduce_bytes(shapes, n)
    ring = comm_bytes_per_gossip(shapes, "ring", n)
    ring_bf16 = comm_bytes_per_gossip(shapes, "ring", n, wire_dtype="bfloat16")
    star = comm_bytes_per_gossip(shapes, "star", n)
    stacked = jax.tree.map(lambda l: jax.ShapeDtypeStruct((n,) + l.shape, l.dtype), shapes)
    # the flat engine behind make_compressed_dense_gossip: int8 payload
    # (incl. chunk padding) + one fp32 scale per (node, scale_chunk) block
    layout = pack_layout(stacked, pad_to=DEFAULT_SCALE_CHUNK)
    ring_int8 = flat_wire_bytes(layout, degree=2, scale_chunk=DEFAULT_SCALE_CHUNK)
    row("centralized all-reduce (every step)", ar)
    row("FedAvg star, Q=100", star / 100)
    row("DSGD/DSGT ring gossip (every step)", ring)
    row("FD ring gossip, Q=10", ring / 10)
    row("FD ring gossip, Q=100 (paper)", ring / 100)
    row("FD ring gossip, Q=100 + bf16 wire", ring_bf16 / 100)
    row("FD ring gossip, Q=100 + int8 diff-coded", ring_int8 / 100)

    print(f"communication bytes per iteration per node -- {arch} "
          f"({p/1e9:.2f} GB fp32 params, N={n}):")
    for r in rows:
        print(f"  {r['strategy']:42s} {r['bytes_per_iter_per_node']/1e6:12.2f} MB"
              f"  ({r['ratio_vs_centralized']:.4f}x centralized)")
    return {"arch": arch, "param_bytes": p, "rows": rows}


if __name__ == "__main__":
    out = main()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/comm_bytes.json", "w") as f:
        json.dump(out, f, indent=2)
