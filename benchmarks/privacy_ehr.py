"""Privacy/utility frontier -> experiments/privacy_ehr.json.

Quantifies what the privacy wire costs in model quality on the paper's
20-hospital cohort: FD-DSGT with the fused engine under a
``dp:sigma=S,clip=1.0`` sweep (per-node L2 clip + Gaussian wire noise in
the quantize epilogue, absorbed by error feedback) plus the secure-agg
on/off pairs, which must change NOTHING -- pairwise transport pads are
exact (masked rounds are bit-identical to unmasked rounds; asserted
here on balanced accuracy, and bit-identically on the sharded wire in
tests/test_privacy.py).

The headline frontier: balanced accuracy vs the (epsilon, delta=1e-5)
moments bound after the run's wire releases (DSGT ships TWO noised
wires per round, x and tracker, so its composition count doubles).
Moderate sigma costs little -- the EF residual absorbs clip + noise
like it absorbs quantization error, so consensus still contracts and
only the effective gradient SNR degrades -- while the epsilon bound
drops by orders of magnitude.

Every row carries the wire-byte column ``tools/bench_guard.py`` gates:
privacy must never grow the wire (pads are in-place bit arithmetic on
the existing int8/scale payloads; noise is generated from checkpointed
counters, never shipped), so ``wire_bytes_per_round`` is identical
across all rows and guarded against regression like every other bench.
The in-script accountant check is the acceptance oracle: the engine's
``dp_epsilon`` metric must match ``analytic_epsilon`` exactly (the
traced twin), and the grid RDP accountant within 2%.

Usage: PYTHONPATH=src python benchmarks/privacy_ehr.py \
           [--rounds 80] [--q 10] [--out experiments/privacy_ehr.json]
       PYTHONPATH=src python benchmarks/privacy_ehr.py --smoke  # tiny CI run
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ehr_mlp import class_weights
from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.privacy import analytic_epsilon, rdp_epsilon
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import make_mlp_loss, mlp_balanced_accuracy, mlp_init
from repro.training.trainer import stack_for_nodes

#: noise multipliers swept (0.0 == the noiseless baseline); clip fixed
#: at 1.0 (the Gaussian-mechanism sensitivity the noise is calibrated to)
DP_SIGMAS = (0.25, 0.5, 1.0)
DP_CLIP = 1.0
DELTA = 1e-5


def run_cell(name: str, privacy, rounds: int, q: int, seed: int = 0,
             alpha0: float = 0.01) -> dict:
    """One privacy-spec cell: FD-DSGT, fused engine, hospital graph,
    equal round budget everywhere."""
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)
    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    engine, state0 = get_engine("fused").simulated(
        w, params, scale_chunk=512, impl="pallas", privacy=privacy,
    )
    loss_fn = make_mlp_loss(class_weights("balanced"))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, inv_sqrt(alpha0), cfg, engine=engine)
    )
    state = init_fl_state(cfg, state0, engine=engine)
    m = {}
    for _ in range(rounds):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    spec = engine.privacy
    wire_releases = rounds * 2  # DSGT: x wire + tracker wire per round
    row = {
        "name": name,
        "privacy": spec.spec(),
        "n_nodes": n,
        "q": q,
        "scale_chunk": 512,
        "topk": None,
        "rounds": rounds,
        "iterations": int(state.step),
        "bal_acc": float(mlp_balanced_accuracy(consensus, xall, yall)),
        "final_loss": float(m["loss"]),
        "consensus_err": float(m["consensus_err"]),
        # the wire-byte column tools/bench_guard.py gates: privacy must
        # never grow the collective operands
        "wire_bytes_per_round": float(m["wire_bytes"]),
    }
    if spec.dp:
        eps_metric = float(m["dp_epsilon"])
        eps_analytic = analytic_epsilon(spec.dp_sigma, wire_releases, DELTA)
        eps_rdp = rdp_epsilon(spec.dp_sigma, wire_releases, DELTA)
        # acceptance oracle: the traced metric IS the analytic bound,
        # and the grid accountant sits within 2% above it
        assert abs(eps_metric - eps_analytic) <= 1e-3 * eps_analytic, (
            eps_metric, eps_analytic)
        assert eps_analytic <= eps_rdp <= 1.02 * eps_analytic, (
            eps_rdp, eps_analytic)
        row.update(epsilon=eps_metric, epsilon_rdp=eps_rdp, delta=DELTA,
                   dp_sigma=spec.dp_sigma, dp_clip=spec.dp_clip,
                   ef_residual_rms=float(m["ef_residual_rms"]))
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=80,
                    help="comm rounds per cell (equal budget everywhere)")
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--out", default="experiments/privacy_ehr.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few rounds, numbers NOT "
                         "representative -- exercises every cell, the "
                         "accountant oracle, and the JSON schema")
    args = ap.parse_args()
    rounds = 6 if args.smoke else args.rounds

    rows = []

    def cell(name, privacy):
        row = run_cell(name, privacy, rounds, args.q)
        rows.append(row)
        eps = row.get("epsilon")
        print(f"{name:28s} bal_acc={row['bal_acc']:.3f} "
              f"eps={'inf' if eps is None else format(eps, '.2f'):>8s} "
              f"wire={row['wire_bytes_per_round']:.0f}B")
        return row

    base = cell("baseline", None)
    sa = cell("secure_agg", "secure_agg")
    # pads are exact: the masked run must be bit-identical, not just close
    assert sa["bal_acc"] == base["bal_acc"], (sa["bal_acc"], base["bal_acc"])
    assert sa["final_loss"] == base["final_loss"]

    for sigma in DP_SIGMAS:
        cell(f"dp_sigma={sigma}", f"dp:sigma={sigma},clip={DP_CLIP}")
    dp = cell("dp_sigma=0.5+secure_agg",
              f"secure_agg+dp:sigma=0.5,clip={DP_CLIP}")
    dp_plain = next(r for r in rows if r["name"] == "dp_sigma=0.5")
    assert dp["bal_acc"] == dp_plain["bal_acc"]  # pads exact under dp too

    # privacy must never grow the wire
    assert len({r["wire_bytes_per_round"] for r in rows}) == 1

    record = {
        "experiment": "privacy_utility_frontier_ehr",
        "cohort": "hospital20 (2103 AD / 7919 MCI, 42 features)",
        "algorithm": "dsgt (fused engine, int8 wire, class-weighted loss)",
        "alpha": "0.01/sqrt(r)",
        "delta": DELTA,
        "dp_clip": DP_CLIP,
        "smoke": bool(args.smoke),
        "note": "bal-acc vs (epsilon, delta) after rounds*2 wire releases "
                "(DSGT ships x + tracker). secure_agg rows are asserted "
                "bit-identical to their unmasked twins (pads are exact; "
                "the sharded transport-level identity is "
                "tests/test_privacy.py). wire_bytes_per_round is identical "
                "across every row -- pads are in-place bit arithmetic and "
                "noise is counter-generated, nothing extra crosses the "
                "wire (tools/bench_guard.py gates the column). The "
                "dp_epsilon metric is asserted against analytic_epsilon "
                "(exact) and the grid RDP accountant (<= 2%) in-script.",
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
