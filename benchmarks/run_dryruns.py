"""Drive the full dry-run sweep: every (arch x shape x mesh) pair.

Each pair runs in a fresh subprocess (jax locks the device count at init;
the dry-run needs 512 placeholder devices while everything else in the
repo must see 1). Results are cached as JSON under experiments/dryrun/ --
re-runs skip completed pairs. Exit code is nonzero if any pair fails.

Usage:
  PYTHONPATH=src python -m benchmarks.run_dryruns [--mesh single|multi|both]
      [--arch ARCH ...] [--shape SHAPE ...] [--q 4] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO, "experiments", "dryrun")

ARCHS = [
    "phi3-medium-14b",
    "recurrentgemma-2b",
    "internvl2-26b",
    "smollm-360m",
    "rwkv6-7b",
    "qwen2.5-32b",
    "dbrx-132b",
    "whisper-medium",
    "llama4-scout-17b-a16e",
    "tinyllama-1.1b",
]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def record_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(OUT_DIR, f"{arch}_{shape}_{mesh}.json")


def run_one(arch: str, shape: str, mesh: str, q: int, timeout: int = 3600) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh,
        "--q", str(q), "--out", OUT_DIR,
    ]
    t0 = time.time()
    proc = subprocess.run(
        cmd, env=env, cwd=REPO, capture_output=True, text=True, timeout=timeout
    )
    dt = time.time() - t0
    if proc.returncode != 0:
        return {
            "arch": arch, "shape": shape, "mesh": mesh, "status": "error",
            "stderr_tail": proc.stderr[-3000:], "wall_s": round(dt, 1),
        }
    path = record_path(arch, shape, mesh)
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        rec["wall_s"] = round(dt, 1)
        return rec
    return {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
            "stderr_tail": "no record written", "wall_s": round(dt, 1)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--arch", nargs="*", default=ARCHS)
    ap.add_argument("--shape", nargs="*", default=SHAPE_NAMES)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    total = 0
    for mesh in meshes:
        for arch in args.arch:
            for shape in args.shape:
                total += 1
                path = record_path(arch, shape, mesh)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        rec = json.load(f)
                    if rec["status"] != "error":  # errors are always retried
                        print(f"[cached] {arch} x {shape} x {mesh}: {rec['status']}")
                        continue
                rec = run_one(arch, shape, mesh, args.q)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" flops/dev={rec['flops']:.3e}"
                        f" coll={rec['collectives']['total_bytes']:.3e}B"
                        f" compile={rec.get('compile_s', 0)}s"
                    )
                elif status == "error":
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                    failures.append((arch, shape, mesh))
                    extra = " :: " + rec.get("stderr_tail", "")[-400:].replace("\n", " | ")
                print(f"[{status}] {arch} x {shape} x {mesh} ({rec.get('wall_s','?')}s){extra}")
                sys.stdout.flush()
    print(f"\n{total - len(failures)}/{total} pairs OK")
    if failures:
        print("FAILURES:")
        for f3 in failures:
            print("  ", f3)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
