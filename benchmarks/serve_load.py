"""Request-replay load generator for the consensus serving path.

Replays a deterministic stream of decode requests through a
``ServeEngine`` while a publisher thread keeps landing fresh consensus
snapshots (the hot-swap path), and measures the serving-side metrics as
first-class columns:

* ``tokens_per_s``            -- generated-token throughput;
* ``us_p50_request`` / ``us_p99_request`` -- request latency tail;
* ``us_swap_pause_mean/max``  -- decode-loop pause per hot swap (the
  atomic slot promotion, staged OFF the decode thread);
* ``staleness_mean/max``      -- rounds the ACTIVE weights lag the
  training frontier at each request completion.

It also times the training->serving handoff itself:
``snapshot_restore`` rows compare the mmap zero-copy snapshot load
(``repro.training.snapshot.load_snapshot``) against the pytree
checkpoint restore (``repro.training.checkpoint.load_fl_state``) on the
SAME consensus payload -- ``speedup_snapshot_load`` is the guarded
ratio, and the default (non-smoke) run adds the tinyllama-1.1b-sized
buffer row the acceptance criterion pins (>= 5x).

Guard semantics (tools/bench_guard.py): ``*_bytes`` columns are
deterministic and gated; ``speedup_*`` ratios are gated with latency
tolerance; absolute ``us_*``, throughput, and staleness columns are
reported, never gated.

  PYTHONPATH=src python benchmarks/serve_load.py --smoke --out experiments/serve_ehr.json
  PYTHONPATH=src python benchmarks/serve_load.py --out experiments/serve_ehr.json
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.fl import FLState  # noqa: E402
from repro.core.packing import pack  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402
from repro.training.checkpoint import load_fl_state, save_fl_state  # noqa: E402
from repro.training.snapshot import (  # noqa: E402
    latest_round,
    load_snapshot,
    write_snapshot,
)

__all__ = ["make_requests", "replay", "restore_comparison"]


def make_requests(n_requests: int, batch: int, prompt_len: int,
                  vocab: int, seed: int = 0) -> List[np.ndarray]:
    """Deterministic request stream: ``n_requests`` prompt batches of
    shape (batch, P) with P jittered in [prompt_len//2, prompt_len]."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        p = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        reqs.append(rng.integers(0, vocab, (batch, p)).astype(np.int32))
    return reqs


def replay(engine: ServeEngine, requests: List[np.ndarray],
           new_tokens: int,
           frontier_fn: Optional[Callable[[], int]] = None,
           refresh_fn: Optional[Callable[[], None]] = None) -> Dict:
    """Replay ``requests`` through ``engine.generate`` and aggregate the
    serving metrics. ``frontier_fn`` reports the live training frontier
    (for the staleness series); ``refresh_fn``, when given, runs between
    requests (e.g. poll the snapshot dir and ``publish_snapshot``).

    Shared by this benchmark (synthetic publisher) and
    ``examples/serve_consensus.py`` (real decentralized training
    publishing concurrently), so both report the SAME columns.
    """
    swap_base = len(engine.swap_pauses)
    lat_s: List[float] = []
    staleness: List[int] = []
    gen_tokens = 0
    t_start = time.perf_counter()
    for prompts in requests:
        if refresh_fn is not None:
            refresh_fn()
        t0 = time.perf_counter()
        out = engine.generate(prompts, max_new_tokens=new_tokens,
                              temperature=0.0)
        lat_s.append(time.perf_counter() - t0)
        gen_tokens += prompts.shape[0] * new_tokens
        if frontier_fn is not None:
            s = engine.staleness(frontier_fn())
            if s is not None:
                staleness.append(s)
    wall = time.perf_counter() - t_start
    pauses = engine.swap_pauses[swap_base:]
    lat_us = np.asarray(lat_s) * 1e6
    row = {
        "n_requests": len(requests),
        "new_tokens": int(new_tokens),
        "gen_tokens": int(gen_tokens),
        "tokens_per_s": float(gen_tokens / wall),
        "us_mean_request": float(lat_us.mean()),
        "us_p50_request": float(np.percentile(lat_us, 50)),
        "us_p99_request": float(np.percentile(lat_us, 99)),
        "n_swaps": len(pauses),
        "us_swap_pause_mean": float(np.mean(pauses) * 1e6) if pauses else 0.0,
        "us_swap_pause_max": float(np.max(pauses) * 1e6) if pauses else 0.0,
    }
    if staleness:
        row["staleness_mean"] = float(np.mean(staleness))
        row["staleness_max"] = int(np.max(staleness))
    return row


def _serve_replay_row(smoke: bool, seed: int = 0) -> Dict:
    """Serve the tinyllama smoke consensus under load while a publisher
    thread trains a synthetic frontier and lands snapshots mid-replay."""
    arch = "tinyllama-1.1b"
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(seed))
    n_nodes = 4
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x * (1.0 + 0.01 * i) for i in range(n_nodes)]),
        params)
    flat, layout = pack(stacked, pad_to=512)

    batch = 2
    n_requests = 6 if smoke else 24
    prompt_len = 8
    new_tokens = 8 if smoke else 16
    publish_every = 2  # requests between published training rounds

    snap_dir = tempfile.mkdtemp(prefix="serve_load_snap_")
    write_snapshot(snap_dir, flat, layout, round_frontier=1)
    tmpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    engine = ServeEngine.from_snapshot(
        bundle, load_snapshot(snap_dir, template=tmpl),
        max_seq=64, batch=batch)

    frontier = {"round": 1}
    stop = threading.Event()

    def publisher():
        # synthetic trainer: advance the frontier steadily, publish a
        # perturbed consensus every few "rounds" through the REAL
        # snapshot files (write -> LATEST -> mmap load happens on the
        # serving side via refresh)
        rnd = 1
        while not stop.is_set():
            time.sleep(0.05)
            rnd += 1
            frontier["round"] = rnd
            if rnd % publish_every == 0:
                write_snapshot(
                    snap_dir,
                    flat * (1.0 + 0.001 * rnd), layout, round_frontier=rnd)

    def refresh():
        newest = latest_round(snap_dir)
        if newest is not None and newest != engine.snapshot_round:
            engine.publish_snapshot(
                load_snapshot(snap_dir, newest, template=tmpl))

    requests = make_requests(n_requests, batch, prompt_len,
                             cfg.vocab_size, seed=seed)
    # warm the jit caches outside the timed window
    engine.generate(requests[0], max_new_tokens=2, temperature=0.0)

    th = threading.Thread(target=publisher, daemon=True)
    th.start()
    try:
        row = replay(engine, requests, new_tokens,
                     frontier_fn=lambda: frontier["round"],
                     refresh_fn=refresh)
    finally:
        stop.set()
        th.join(timeout=5)
        shutil.rmtree(snap_dir, ignore_errors=True)
    row.update({
        "name": f"serve_replay__{arch}_smoke",
        "total_params": int(cfg.param_count()),
        "n_nodes": n_nodes,
        "batch": batch,
        "prompt_len": prompt_len,
        "rounds_published": int(frontier["round"]),
    })
    return row


def restore_comparison(name: str, total_params: int, n_leaves: int = 8,
                       n_nodes: int = 1, seed: int = 0,
                       repeats: int = 5) -> Dict:
    """Time mmap snapshot load vs pytree checkpoint restore of the SAME
    consensus payload (``total_params`` fp32 weights in ``n_leaves``
    equal leaves), medians over ``repeats``.

    The checkpoint side is the repo's real resume path
    (``save_fl_state``/``load_fl_state``: compressed npz + per-leaf
    astype + unflatten); the snapshot side is
    ``load_snapshot`` (header parse + ``np.memmap`` + per-leaf views --
    bytes fault in lazily). ``us_snapshot_load_touched`` additionally
    forces a full read of the mapped blob, for reading honesty.
    """
    rng = np.random.default_rng(seed)
    per = total_params // n_leaves
    params = {
        f"layer{i:02d}": np.stack([
            rng.standard_normal(per, dtype=np.float32)
            for _ in range(n_nodes)])
        for i in range(n_leaves)
    }
    flat, layout = pack(params, pad_to=512)
    flat = np.asarray(flat)

    work = tempfile.mkdtemp(prefix="serve_load_restore_")
    try:
        snap_dir = os.path.join(work, "snap")
        ckpt_dir = os.path.join(work, "ckpt")
        write_snapshot(snap_dir, flat, layout, round_frontier=1)
        consensus = jax.tree_util.tree_map(
            lambda x: x.mean(axis=0, keepdims=True), params)
        state = FLState(step=np.int32(0), params=consensus, tracker=None,
                        prev_grad=None, comm=None)
        save_fl_state(ckpt_dir, state)

        t_snap, t_touch, t_ckpt = [], [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            snap = load_snapshot(snap_dir)
            t_snap.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            float(np.add.reduce(snap.flat, dtype=np.float64))  # fault all
            t_touch.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            load_fl_state(ckpt_dir, state)
            t_ckpt.append(time.perf_counter() - t0)
        us_snap = float(np.median(t_snap) * 1e6)
        us_touch = float(np.median(t_touch) * 1e6)
        us_ckpt = float(np.median(t_ckpt) * 1e6)
        snap_bytes = os.path.getsize(
            os.path.join(snap_dir, snap.header["blob"]))
        ckpt_bytes = os.path.getsize(os.path.join(ckpt_dir, "state.npz"))
    finally:
        shutil.rmtree(work, ignore_errors=True)
    return {
        "name": name,
        "total_params": int(layout.total),
        "n_leaves": n_leaves,
        "n_nodes": n_nodes,
        "snapshot_bytes": int(snap_bytes),
        "checkpoint_bytes": int(ckpt_bytes),
        "us_snapshot_load": us_snap,
        "us_snapshot_load_touched": us_touch,
        "us_checkpoint_restore": us_ckpt,
        "speedup_snapshot_load": us_ckpt / us_snap,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: smoke model replay + small restore "
                         "row (skips the tinyllama-1.1b-sized buffer)")
    ap.add_argument("--out", default="experiments/serve_ehr.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows: List[Dict] = []
    print("serving replay under load (hot-swap publisher running)...")
    rows.append(_serve_replay_row(smoke=args.smoke, seed=args.seed))
    r = rows[-1]
    print(f"  {r['name']}: {r['tokens_per_s']:.1f} tok/s, "
          f"p50={r['us_p50_request']/1e3:.1f}ms "
          f"p99={r['us_p99_request']/1e3:.1f}ms, "
          f"{r['n_swaps']} swaps (pause mean "
          f"{r['us_swap_pause_mean']:.1f}us), "
          f"staleness mean={r.get('staleness_mean', 0):.1f} "
          f"max={r.get('staleness_max', 0)}")

    print("restore comparison (smoke-sized consensus buffer)...")
    smoke_total = int(get_config("tinyllama-1.1b", smoke=True).param_count())
    rows.append(restore_comparison("snapshot_restore__smoke",
                                   smoke_total, seed=args.seed))
    r = rows[-1]
    print(f"  {r['name']}: mmap {r['us_snapshot_load']:.0f}us vs npz "
          f"restore {r['us_checkpoint_restore']:.0f}us -> "
          f"{r['speedup_snapshot_load']:.1f}x")

    if not args.smoke:
        full_total = int(get_config("tinyllama-1.1b",
                                    smoke=False).param_count())
        print(f"restore comparison (tinyllama-1.1b-sized buffer: "
              f"{full_total/1e9:.2f}B params, "
              f"{full_total*4/1e9:.1f} GB fp32)...")
        rows.append(restore_comparison("snapshot_restore__tinyllama-1.1b",
                                       full_total, seed=args.seed,
                                       repeats=3))
        r = rows[-1]
        print(f"  {r['name']}: mmap {r['us_snapshot_load']:.0f}us vs npz "
              f"restore {r['us_checkpoint_restore']/1e6:.1f}s -> "
              f"{r['speedup_snapshot_load']:.0f}x")
        if r["speedup_snapshot_load"] < 5.0:
            print("  WARNING: below the 5x acceptance threshold")

    record = {
        "bench": "serve_consensus_load",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"-> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
