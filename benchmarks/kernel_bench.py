"""Kernel micro-benchmarks: wall time of the jnp reference paths on host +
interpret-mode correctness spot checks.

NOTE (honest measurement): this container is CPU-only; Pallas interpret
mode executes the kernel body in Python and its wall time says nothing
about TPU performance. What we CAN measure here is (a) the pure-jnp
chunked/associative formulations that the kernels tile (their relative
scaling with sequence length validates the algorithmic complexity), and
(b) per-call overhead of the naive references they replace. TPU speedups
must come from the roofline analysis, not these timings.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_attention() -> List[Dict]:
    from repro.models.attention import _sdpa

    rows = []
    rng = np.random.default_rng(0)
    for seq in (256, 512, 1024):
        q = jnp.asarray(rng.normal(size=(1, seq, 4, 64)), jnp.float32)
        full = jax.jit(lambda q: _sdpa(q, q, q, causal=True, window=0))
        win = jax.jit(lambda q: _sdpa(q, q, q, causal=True, window=128))
        rows.append({
            "name": f"attention_ref_s{seq}",
            "us_full": _time(full, q),
            "us_window128": _time(win, q),
        })
    return rows


def bench_wkv6() -> List[Dict]:
    from repro.kernels.rwkv6_scan.ref import wkv6_ref
    from repro.models.rwkv6 import wkv6_chunked

    rows = []
    rng = np.random.default_rng(1)
    for seq in (256, 1024):
        bh, hd = 2, 64
        r, k, v = (jnp.asarray(rng.normal(size=(bh, seq, hd)), jnp.float32) for _ in range(3))
        lw = -jnp.exp(jnp.asarray(rng.normal(size=(bh, seq, hd)), jnp.float32) - 1)
        u = jnp.asarray(rng.normal(size=(bh, hd)), jnp.float32)
        s0 = jnp.zeros((bh, hd, hd))
        naive = jax.jit(lambda *a: wkv6_ref(*a))
        r4, k4, v4, lw4 = (a[:, :, None] for a in (r, k, v, lw))
        chunked = jax.jit(
            lambda r4, k4, v4, lw4, u, s0: wkv6_chunked(r4, k4, v4, lw4, u[:1], s0[:, None], chunk=64)
        )
        rows.append({
            "name": f"wkv6_s{seq}",
            "us_naive_scan": _time(naive, r, k, v, lw, u, s0),
            "us_chunked": _time(chunked, r4, k4, v4, lw4, u, s0),
        })
    return rows


def bench_rglru() -> List[Dict]:
    from repro.kernels.rglru_scan.ref import rglru_ref
    from repro.models.rglru import rglru_scan_assoc

    rows = []
    rng = np.random.default_rng(2)
    for seq in (256, 1024, 4096):
        b, w = 2, 256
        la = -jnp.exp(jnp.asarray(rng.normal(size=(b, seq, w)), jnp.float32))
        bb = jnp.asarray(rng.normal(size=(b, seq, w)), jnp.float32)
        h0 = jnp.zeros((b, w))
        naive = jax.jit(lambda *a: rglru_ref(*a))
        assoc = jax.jit(lambda *a: rglru_scan_assoc(*a))
        rows.append({
            "name": f"rglru_s{seq}",
            "us_naive_scan": _time(naive, la, bb, h0),
            "us_assoc_scan": _time(assoc, la, bb, h0),
        })
    return rows


def main() -> List[Dict]:
    all_rows = []
    for fn in (bench_attention, bench_wkv6, bench_rglru):
        rows = fn()
        all_rows.extend(rows)
        for r in rows:
            extras = {k: v for k, v in r.items() if k != "name"}
            print(f"  {r['name']:22s} " + "  ".join(f"{k}={v:10.1f}" for k, v in extras.items()))
    return all_rows


if __name__ == "__main__":
    main()
