"""Roofline analysis over the dry-run records (deliverable (g)).

Per (arch x shape) on the single-pod mesh, derive the three roofline terms
from the while-aware HLO accounting of the compiled dry-run:

    compute    = HLO_FLOPs      / (chips x 197e12 FLOP/s)     [per device]
    memory     = HLO_bytes      / (chips x 819e9  B/s)
    collective = collective_B   / (chips x 50e9   B/s/link)

(dry-run records store PER-DEVICE quantities already -- the SPMD
partitioner emitted per-device programs -- so `chips` division is implicit
and the terms below use the per-device numbers directly.)

Also reports MODEL_FLOPS = 6*N*D (N = params, active for MoE; D = tokens)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs_global, flagging remat /
redundancy waste, plus the dominant term and a one-line lever.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")

SHAPE_TOKENS = {
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (1, 128),  # one new token per request
    "long_500k": (1, 1),
}


def load_records(mesh: str = "single", suffix: str = "") -> List[Dict]:
    """Baseline records are exactly {arch}_{shape}_{mesh}{suffix}.json;
    §Perf variant records (suffixes _blocked/_wire-*/_podq*/_q*/_dsgd) are
    loaded explicitly by passing their suffix."""
    from benchmarks.run_dryruns import ARCHS, SHAPE_NAMES

    recs = []
    for arch in ARCHS:
        for shape in SHAPE_NAMES:
            path = os.path.join(DRYRUN_DIR, f"{arch}_{shape}_{mesh}{suffix}.json")
            if os.path.exists(path):
                with open(path) as f:
                    recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return {
            "arch": rec["arch"], "shape": rec["shape"], "status": rec["status"],
            "reason": rec.get("reason", ""),
        }
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["traffic_bytes"] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / ICI_BW
    # cross-node share (the FL / paper-relevant link), when recorded
    cross = rec["collectives"].get("cross_node_bytes")
    t_cross = (cross / ICI_BW) if cross is not None else None
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    seq, batch = SHAPE_TOKENS[rec["shape"]]
    tokens_global = seq * batch
    if rec["kind"] == "train" and rec.get("q"):
        tokens_global *= rec["q"]
    if rec["arch"] == "whisper-medium" and rec["kind"] != "train":
        # whisper prefill prompts are capped at 448 decoder tokens
        tokens_global = min(seq, 448) * batch
    n_active = rec.get("active_params") or rec.get("model_params") or 0
    model_flops_global = 6.0 * n_active * tokens_global if rec["kind"] == "train" else 2.0 * n_active * tokens_global
    hlo_global = rec["flops"] * rec["n_chips"]
    ratio = model_flops_global / hlo_global if hlo_global else 0.0

    levers = {
        "compute": "raise per-chip utilization: bigger per-node batch or lower remat recompute",
        "memory": "cut HBM traffic: fused (flash) attention, chunked loss, bf16 activations",
        "collective": "cut wire bytes: larger Q, bf16 gossip wire, hierarchical pod gossip",
    }
    row = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "status": "ok",
        "kind": rec["kind"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_cross_node_s": t_cross,
        "dominant": dominant,
        "bound_fraction": terms[dominant] / (sum(terms.values()) + 1e-30),
        "model_flops_global": model_flops_global,
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "lever": levers[dominant],
        "memory_temp_bytes": rec["memory"]["temp_bytes"],
        "memory_arg_bytes": rec["memory"]["argument_bytes"],
    }
    two = rec.get("two_axis")
    if two:
        # two-axis (gossip_node, model_shard) records: the per-shard wire
        # column prices one shard's gossip collective against its slice
        # of ICI -- per-shard bytes x shards == the whole node's wire
        row["model_shards"] = two["model_shards"]
        row["wire_bytes_per_shard_per_round"] = two[
            "wire_bytes_per_shard_per_round"]
        row["t_wire_per_shard_s"] = (
            two["wire_bytes_per_shard_per_round"] / ICI_BW)
    return row


def format_table(rows: List[Dict]) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'compute(s)':>11s} {'memory(s)':>11s} "
        f"{'collect(s)':>11s} {'dominant':>10s} {'useful':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:26s} {r['shape']:12s} {'SKIP: ' + r.get('reason', '')[:60]}")
            continue
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['t_compute_s']:11.4f} "
            f"{r['t_memory_s']:11.4f} {r['t_collective_s']:11.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f}"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.mesh)
    # two-axis (gossip_node, model_shard) dry-run variants, when present
    # (launch/dryrun.py --fl-shard-model): rows gain per-shard wire columns
    recs += load_records(args.mesh, suffix="_sharded_fused_shardmodel_q2")
    rows = [roofline_row(r) for r in recs]
    rows = [r for r in rows if r]
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print(format_table(rows))
        oks = [r for r in rows if r.get("status") == "ok"]
        if oks:
            worst = min(oks, key=lambda r: r["useful_ratio"])
            collbound = max(oks, key=lambda r: r["t_collective_s"])
            print(f"\nworst useful-ratio: {worst['arch']} x {worst['shape']} ({worst['useful_ratio']:.3f})")
            print(f"most collective-bound: {collbound['arch']} x {collbound['shape']} ({collbound['t_collective_s']:.3f}s)")


if __name__ == "__main__":
    main()
