"""Benchmark harness -- one function per paper table/figure + system tables.

  fig2            Fig. 2: convergence vs communication rounds (4 algorithms)
  thm1            Theorem 1: linear speedup of DSGT in N
  comm_bytes      communication bytes/iteration table (ring FD vs baselines)
  kernels         kernel-formulation micro-timings (host jnp paths)
  roofline        3-term roofline over the dry-run records (if present)

Prints ``name,us_per_call,derived`` CSV lines at the end, one per table.
Run: PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced iteration counts")
    args = ap.parse_args()

    os.makedirs(os.path.join(REPO, "experiments"), exist_ok=True)
    csv_rows: List[Dict] = []

    # --- Fig. 2: communication-round convergence --------------------------
    from benchmarks import fig2_comm_rounds

    iters = 600 if args.fast else 3000
    print(f"\n=== fig2_comm_rounds (iterations={iters}) ===")
    fig2, us = _timed(fig2_comm_rounds.main, iterations=iters)
    with open(os.path.join(REPO, "experiments", "fig2_results.json"), "w") as f:
        json.dump(fig2, f)
    csv_rows.append({
        "name": "fig2_comm_rounds", "us_per_call": us,
        "derived": f"fd_dsgt_comm_saving={fig2['_derived']['fd_dsgt_saving']:.0f}x",
    })

    # --- Theorem 1: linear speedup ----------------------------------------
    from benchmarks import thm1_speedup

    steps = 150 if args.fast else 400
    print(f"\n=== thm1_speedup (T={steps}) ===")
    thm1, us = _timed(thm1_speedup.main, t_steps=steps, seeds=2 if args.fast else 3)
    with open(os.path.join(REPO, "experiments", "thm1_results.json"), "w") as f:
        json.dump(thm1, f)
    csv_rows.append({
        "name": "thm1_linear_speedup", "us_per_call": us,
        "derived": f"ratio_4_8={thm1['ratio_4_8']:.2f};ratio_8_16={thm1['ratio_8_16']:.2f}",
    })

    # --- communication bytes ----------------------------------------------
    from benchmarks import comm_bytes

    print("\n=== comm_bytes ===")
    cb, us = _timed(comm_bytes.main)
    with open(os.path.join(REPO, "experiments", "comm_bytes.json"), "w") as f:
        json.dump(cb, f, indent=2)
    q100 = [r for r in cb["rows"] if "Q=100 (paper)" in r["strategy"]][0]
    csv_rows.append({
        "name": "comm_bytes_table", "us_per_call": us,
        "derived": f"fd_q100_vs_centralized={q100['ratio_vs_centralized']:.5f}x",
    })

    # --- kernel micro-timings ----------------------------------------------
    from benchmarks import kernel_bench

    print("\n=== kernel_bench ===")
    kb, us = _timed(kernel_bench.main)
    csv_rows.append({"name": "kernel_bench", "us_per_call": us, "derived": f"rows={len(kb)}"})

    # --- beyond-paper ablations ---------------------------------------------
    from benchmarks import ablations

    print("\n=== ablations (topology spectral gap, client drift vs Q) ===")
    ab, us = _timed(ablations.main)
    with open(os.path.join(REPO, "experiments", "ablations.json"), "w") as f:
        json.dump(ab, f, indent=2)
    csv_rows.append({
        "name": "ablations", "us_per_call": us,
        "derived": (
            f"dsgd_ring_vs_complete_consensus="
            f"{ab['topology']['ring']['dsgd_consensus']/ab['topology']['complete']['dsgd_consensus']:.1f}x;"
            f"q60_drift_penalty_het8={ab['drift']['8.0']['q60_penalty']:.1f}x"
        ),
    })

    # --- roofline (requires dry-run records) -------------------------------
    from benchmarks import roofline

    print("\n=== roofline (single-pod dry-run records) ===")
    recs = roofline.load_records("single")
    if recs:
        rows = [roofline.roofline_row(r) for r in recs]
        print(roofline.format_table([r for r in rows if r]))
        oks = [r for r in rows if r and r.get("status") == "ok"]
        dom = {}
        for r in oks:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        csv_rows.append({
            "name": "roofline", "us_per_call": 0.0,
            "derived": f"pairs={len(oks)};dominant=" + "/".join(f"{k}:{v}" for k, v in sorted(dom.items())),
        })
    else:
        print("  (no dry-run records; run benchmarks/run_dryruns.py first)")

    # --- CSV ----------------------------------------------------------------
    print("\nname,us_per_call,derived")
    for r in csv_rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
