"""Node-churn robustness experiment -> experiments/churn_ehr.json.

Quantifies what TIME-VARYING topology costs in model quality on the
paper's 20-hospital cohort: FD-DSGT with the fused engine under the
``node_churn`` TopologyProgram (core.dynamics) at several node-downtime
fractions -- every round, each hospital is offline with probability
``p_down`` in persistent blocks of ``mean_downtime`` rounds, its mixing
weight folded into its self-loop while it keeps taking local steps.
The equal-iteration-budget comparison against the static graph is the
headline: how much balanced accuracy does a churning referral network
cost, and where does it fall off a cliff?

Why moderate churn is cheap here: a down node only pauses its CONSENSUS
progress, not its optimization -- with EF-compressed gossip the
difference-coded wire re-injects the missed mass when the node returns,
and the effective (expected) mixing matrix W_eff = E[W_r] still
satisfies Assumption 1 with a spectral gap shrunk by roughly the uptime
fraction squared (both endpoints must be up), so consensus equilibrates
higher but does not diverge until the graph is offline most of the time.

Also reports an ``edge_failure`` row at matched expected edge loss, to
separate "whole nodes vanish" from "individual links flap" at the same
average connectivity.

Usage: PYTHONPATH=src python benchmarks/churn_ehr.py \
           [--rounds 120] [--q 10] [--out experiments/churn_ehr.json]
       PYTHONPATH=src python benchmarks/churn_ehr.py --smoke   # tiny CI run
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ehr_mlp import class_weights
from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import make_mlp_loss, mlp_balanced_accuracy, mlp_init
from repro.training.trainer import stack_for_nodes

#: downtime fractions swept (0.0 == the static graph baseline)
DOWNTIME_FRACTIONS = (0.0, 0.1, 0.25, 0.5)
MEAN_DOWNTIME = 5  # rounds per outage block


def run_cell(program: str | None, rounds: int, q: int, seed: int = 0) -> dict:
    """One program cell: FD-DSGT, fused engine, hospital graph."""
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)
    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    engine, state0 = get_engine("fused").simulated(
        w, params, scale_chunk=512, impl="pallas", topology_program=program,
    )
    loss_fn = make_mlp_loss(class_weights("balanced"))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, inv_sqrt(0.02), cfg, engine=engine)
    )
    state = init_fl_state(cfg, state0, engine=engine)
    m, edge_fracs = {}, []
    for _ in range(rounds):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
        if "edge_fraction" in m:
            edge_fracs.append(float(m["edge_fraction"]))
    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    return {
        "program": engine.topology_program.spec(),
        "rounds": rounds,
        "q": q,
        "iterations": int(state.step),
        "bal_acc": float(mlp_balanced_accuracy(consensus, xall, yall)),
        "final_loss": float(m["loss"]),
        "consensus_err": float(m["consensus_err"]),
        "mean_edge_fraction": (
            float(np.mean(edge_fracs)) if edge_fracs else 1.0
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=120,
                    help="comm rounds per cell (equal budget everywhere)")
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--out", default="experiments/churn_ehr.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: few rounds, numbers NOT "
                         "representative -- exercises every cell and the "
                         "JSON schema")
    args = ap.parse_args()
    rounds = 6 if args.smoke else args.rounds

    cells = []
    for p_down in DOWNTIME_FRACTIONS:
        program = (
            None if p_down == 0.0 else
            f"node_churn:p_down={p_down},mean_downtime={MEAN_DOWNTIME},seed=0"
        )
        cell = run_cell(program, rounds, args.q)
        cell["p_down"] = p_down
        cells.append(cell)
        print(f"p_down={p_down:4.2f} edges_up~{cell['mean_edge_fraction']:.2f} "
              f"bal_acc={cell['bal_acc']:.3f} "
              f"cons_err={cell['consensus_err']:.2e}")

    # matched-average-connectivity link-flap comparison: a node-churn
    # fraction p isolates an edge with prob 1-(1-p)^2; pick the middle
    # sweep point's equivalent per-edge failure rate
    p_mid = DOWNTIME_FRACTIONS[2]
    p_edge = round(1.0 - (1.0 - p_mid) ** 2, 4)
    flap = run_cell(f"edge_failure:p={p_edge},seed=0", rounds, args.q)
    flap["p_down"] = None
    flap["matched_to_p_down"] = p_mid
    cells.append(flap)
    print(f"edge_failure p={p_edge} (matched to p_down={p_mid}) "
          f"bal_acc={flap['bal_acc']:.3f}")

    static_acc = cells[0]["bal_acc"]
    record = {
        "experiment": "node_churn_ehr",
        "cohort": "hospital20 (2103 AD / 7919 MCI, 42 features)",
        "algorithm": "dsgt (fused engine, int8 wire, class-weighted loss)",
        "alpha": "0.02/sqrt(r)",
        "mean_downtime_rounds": MEAN_DOWNTIME,
        "smoke": bool(args.smoke),
        "note": "equal iteration budget per cell; node_churn masks ALL "
                "of a down hospital's links for persistent blocks "
                "(weight folded into its self-loop; it keeps local-"
                "stepping), edge_failure flaps individual links i.i.d. "
                "per round at the matched expected edge loss. The "
                "program gates mixing inside ONE compiled round "
                "function -- zero recompiles, zero extra collectives "
                "(tests/test_dynamics.py).",
        "cells": cells,
        "summary": {
            str(c["p_down"]): {
                "bal_acc": c["bal_acc"],
                "bal_acc_delta_vs_static": c["bal_acc"] - static_acc,
            }
            for c in cells if c["p_down"] is not None
        },
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
