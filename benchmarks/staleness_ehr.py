"""One-round-staleness convergence experiment -> experiments/staleness_ehr.json.

Quantifies what the PipelinedSchedule's one-round-stale mixing costs in
model quality on the paper's 20-hospital cohort: FD-DSGT with the fused
engine, sequential vs pipelined, at Q in {1, 4, 16} local steps per
communication round (equal ITERATION budget across Q, so every cell sees
the same number of gradient steps).

Why staleness is benign here: stale gossip is the second-order recurrence
``x^{r+1} = W_self x^r + W_off x^{r-1}`` whose disagreement modes are
stable whenever ``z^2 = w_self z + (lam - w_self)`` has roots inside the
unit circle for every eigenvalue ``lam`` of W -- on the hospital graph's
Metropolis W (lam_min ~ -0.39, mean w_self ~ 0.32) the worst root modulus
is ~0.84, i.e. mixing at roughly half the sequential rate: consensus
error equilibrates HIGHER under gradient noise but does not diverge, and
the consensus model's balanced accuracy lands within the run-to-run
noise of sequential (asserted <= 0.02 loss in tests/test_schedule.py).

Usage: PYTHONPATH=src python benchmarks/staleness_ehr.py \
           [--rounds-at-q1 320] [--out experiments/staleness_ehr.json]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ehr_mlp import class_weights
from repro.core import (
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import make_mlp_loss, mlp_balanced_accuracy, mlp_init
from repro.training.trainer import stack_for_nodes


def run_cell(q: int, schedule: str, rounds: int, seed: int = 0,
             topk=None) -> dict:
    """One (Q, schedule) cell: FD-DSGT, fused engine, hospital graph."""
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)
    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    engine, state0 = get_engine("fused").simulated(
        w, params, scale_chunk=512, topk=topk, impl="pallas",
        round_schedule=schedule,
    )
    loss_fn = make_mlp_loss(class_weights("balanced"))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, inv_sqrt(0.02), cfg, engine=engine)
    )
    state = init_fl_state(cfg, state0, engine=engine)
    m = {}
    for _ in range(rounds):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    return {
        "q": q,
        "schedule": schedule,
        "rounds": rounds,
        "iterations": int(state.step),
        "bal_acc": float(mlp_balanced_accuracy(consensus, xall, yall)),
        "final_loss": float(m["loss"]),
        "consensus_err": float(m["consensus_err"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds-at-q1", type=int, default=320,
                    help="comm rounds at Q=1; Q>1 cells run rounds/Q so "
                         "every cell sees the same iteration budget")
    ap.add_argument("--out", default="experiments/staleness_ehr.json")
    args = ap.parse_args()

    cells = []
    for q in (1, 4, 16):
        rounds = max(1, args.rounds_at_q1 // q)
        for schedule in ("sequential", "pipelined"):
            cell = run_cell(q, schedule, rounds)
            cells.append(cell)
            print(f"Q={q:2d} {schedule:10s} rounds={rounds:4d} "
                  f"bal_acc={cell['bal_acc']:.3f} "
                  f"cons_err={cell['consensus_err']:.2e}")

    by_q = {}
    for q in (1, 4, 16):
        seq = next(c for c in cells if c["q"] == q and c["schedule"] == "sequential")
        pipe = next(c for c in cells if c["q"] == q and c["schedule"] == "pipelined")
        by_q[str(q)] = {
            "bal_acc_sequential": seq["bal_acc"],
            "bal_acc_pipelined": pipe["bal_acc"],
            "bal_acc_delta": seq["bal_acc"] - pipe["bal_acc"],
            "consensus_err_ratio": (
                pipe["consensus_err"] / max(seq["consensus_err"], 1e-12)
            ),
        }
        print(f"Q={q:2d} staleness cost: "
              f"{by_q[str(q)]['bal_acc_delta']:+.4f} balanced accuracy")

    record = {
        "experiment": "one_round_staleness_ehr",
        "cohort": "hospital20 (2103 AD / 7919 MCI, 42 features)",
        "algorithm": "dsgt (fused engine, int8 wire, class-weighted loss)",
        "alpha": "0.02/sqrt(r)",
        "note": "equal iteration budget per cell; pipelined = "
                "sequential-with-one-round-delay (stale gossip is a "
                "stable second-order recurrence on this W -- worst "
                "disagreement-mode root ~0.84), so it trades a higher "
                "consensus-error plateau for a hidden collective; "
                "balanced-accuracy cost stays within noise "
                "(<= 0.02 asserted in tests/test_schedule.py)",
        "cells": cells,
        "summary": by_q,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
