"""End-to-end driver: train a decoder with FD-DSGT for a few hundred
steps (deliverable (b): the end-to-end training example).

Two modes:

  * default -- a 100M-class llama-family config (d=512, 8 layers, 32k
    vocab) across 4 FL nodes on a ring with Q=5 local steps per round,
    through the simulated tree engine (single device, dense-W gossip);

  * decentralized -- ``--fl-engine sharded_fused`` builds the round on a
    real ``(gossip_node, model_shard)`` device mesh (forced host devices
    off-TPU): each node's parameters live as one flat buffer whose
    columns tile over the model axis, the wire stage runs one fused pass
    per (node, shard) tile, and the int8 gossip collective stays on the
    node axis only. ``--arch smollm-360m`` swaps in the SmolLM-360M
    config (``--smoke`` shrinks it to a 2-layer smoke variant that runs
    in seconds on CPU). The other round axes ride along:
    ``--fl-schedule/--fl-topology-program/--fl-node-program/--fl-privacy``.

  PYTHONPATH=src python examples/train_100m.py --rounds 60
  PYTHONPATH=src python examples/train_100m.py --arch smollm-360m --smoke \
      --fl-engine sharded_fused --model-shards 2 --topk 8 --rounds 6
"""

# XLA locks the device count at first jax initialization, so the mesh
# size must be decided from argv BEFORE importing jax.
import os
import sys


def _argv_value(flag, default):
    if flag in sys.argv:
        i = sys.argv.index(flag)
        if i + 1 < len(sys.argv):
            return sys.argv[i + 1]
    return default


if _argv_value("--fl-engine", "tree") == "sharded_fused":
    _n = int(_argv_value("--nodes", "4"))
    _s = int(_argv_value("--model-shards", "1"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n * _s} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse  # noqa: E402
import csv  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import FLRunConfig, get_config  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.data.tokens import make_fl_token_batches  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.training.checkpoint import save_fl_state  # noqa: E402
from repro.training.trainer import (  # noqa: E402
    stack_for_nodes,
    train_decentralized,
)


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m",
        family="dense",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=32000,
        head_dim=64,
        source="100M-class llama-family config (this repo)",
    )


def build_sharded_engine(args, stacked):
    """The two-axis (gossip_node, model_shard) engine on forced host
    devices: node ring over 'data', flat-buffer columns over 'model'."""
    from repro.core import ShardedFusedEngine

    shards = args.model_shards
    mesh = jax.make_mesh((args.nodes, shards), ("data", "model"))
    engine = ShardedFusedEngine.from_mesh(
        mesh, ("data",), stacked, scale_chunk=args.scale_chunk,
        topk=args.topk, impl="jnp",
        model_axis="model" if shards > 1 else None,
        round_schedule=args.fl_schedule,
        topology_program=args.fl_topology_program,
        node_program=args.fl_node_program,
        privacy=args.fl_privacy,
    )
    return engine, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--q", type=int, default=5)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--alpha0", type=float, default=0.4)
    ap.add_argument("--ckpt", default="experiments/ckpt_100m")
    ap.add_argument("--arch", default="llama-100m",
                    help="'llama-100m' (built in) or a registry arch like "
                         "'smollm-360m'")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's smoke variant (registry archs only)")
    ap.add_argument("--fl-engine", default="tree",
                    choices=("tree", "flat", "fused", "sharded_fused"),
                    help="'sharded_fused' trains on a real (gossip_node, "
                         "model_shard) mesh of forced host devices")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="size of the mesh's model axis (sharded_fused "
                         "only): each node's flat buffer tiles over it")
    ap.add_argument("--scale-chunk", type=int, default=256)
    ap.add_argument("--topk", type=int, default=None,
                    help="fused engines: ship only the k largest payload "
                         "columns per scale chunk")
    ap.add_argument("--fl-schedule", default=None,
                    help="round time layout, e.g. 'pipelined' or "
                         "'bounded_staleness:k=2'")
    ap.add_argument("--fl-topology-program", default=None,
                    help="per-round graph dynamics, e.g. "
                         "'node_churn:p_down=0.2,mean_downtime=5'")
    ap.add_argument("--fl-node-program", default=None,
                    help="per-node heterogeneity, e.g. "
                         "'slow_uplink:frac=0.25,k_scale=0.25'")
    ap.add_argument("--fl-privacy", default=None,
                    help="wire privacy epilogue, e.g. "
                         "'secure_agg+dp:sigma=0.5,clip=1.0'")
    args = ap.parse_args()

    if args.arch == "llama-100m":
        if args.smoke:
            ap.error("--smoke needs a registry arch (e.g. --arch smollm-360m)")
        cfg = model_100m()
    else:
        cfg = get_config(args.arch, smoke=args.smoke)
    bundle = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"{args.nodes} nodes x Q={args.q}, {args.rounds} rounds "
          f"= {args.rounds*args.q} training steps, "
          f"engine={args.fl_engine}"
          + (f" x {args.model_shards} model shards"
             if args.fl_engine == "sharded_fused" else ""))

    run = FLRunConfig(algorithm="dsgt", q=args.q, topology="ring",
                      n_nodes=args.nodes, batch_per_node=args.batch_per_node,
                      alpha0=args.alpha0, schedule="constant")
    stream = make_fl_token_batches(cfg.vocab_size, args.nodes,
                                   args.batch_per_node, args.seq_len, q=1, seed=0)
    step_batches = ({k: v[0] for k, v in b.items()} for b in stream)

    params0 = bundle.init_fn(jax.random.key(0))
    engine_arg = args.fl_engine
    mesh = None
    if args.fl_engine == "sharded_fused":
        stacked = stack_for_nodes(params0, args.nodes)
        engine_arg, mesh = build_sharded_engine(args, stacked)
        params0 = stacked
        knobs = dict(engine=engine_arg)
    else:
        knobs = dict(engine=engine_arg, topk=args.topk,
                     round_schedule=args.fl_schedule,
                     topology_program=args.fl_topology_program,
                     node_program=args.fl_node_program,
                     privacy=args.fl_privacy)
        if args.fl_engine in ("flat", "fused"):
            knobs["scale_chunk"] = args.scale_chunk

    t0 = time.time()
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        result = train_decentralized(
            bundle.loss_fn, params0, run,
            step_batches, rounds=args.rounds, log_every=2, **knobs,
        )
    dt = time.time() - t0
    rows = result.history.rows()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/train_100m_metrics.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted(rows[0]))
        w.writeheader()
        w.writerows(rows)
    eng = engine_arg if isinstance(engine_arg, str) else engine_arg.name
    save_fl_state(args.ckpt, result.state, extra={"arch": cfg.name},
                  engine=None if isinstance(engine_arg, str) else engine_arg)
    print(f"\nloss {rows[0]['loss']:.3f} -> {rows[-1]['loss']:.3f} "
          f"({int(rows[-1]['iteration'])} steps, {dt/60:.1f} min, "
          f"{dt/max(1,int(rows[-1]['iteration'])):.1f}s/step, engine={eng})")
    print(f"metrics -> experiments/train_100m_metrics.csv; ckpt -> {args.ckpt}")


def _nullcontext():
    import contextlib

    return contextlib.nullcontext()


if __name__ == "__main__":
    main()
