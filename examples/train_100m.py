"""End-to-end driver: train a ~100M-parameter decoder with FD-DSGT for a
few hundred steps (deliverable (b): the end-to-end training example).

The model is a 100M-class llama-family config (d=512, 8 layers, 32k vocab)
trained across 4 FL nodes on a ring with Q=5 local steps per round. On the
single CPU core of this container a full run (--rounds 60 == 300 steps)
takes a while; --rounds 10 gives a quick demonstration. Loss on the
structured synthetic token stream drops measurably within the run; metrics
land in experiments/train_100m_metrics.csv and a checkpoint is written.

  PYTHONPATH=src python examples/train_100m.py --rounds 60
"""

import argparse
import csv
import dataclasses
import os
import time

import jax

from repro.configs import FLRunConfig
from repro.configs.base import ModelConfig
from repro.data.tokens import make_fl_token_batches
from repro.models import build_model
from repro.training.checkpoint import save_fl_state
from repro.training.trainer import train_decentralized


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m",
        family="dense",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=32000,
        head_dim=64,
        source="100M-class llama-family config (this repo)",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--q", type=int, default=5)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--alpha0", type=float, default=0.4)
    ap.add_argument("--ckpt", default="experiments/ckpt_100m")
    args = ap.parse_args()

    cfg = model_100m()
    bundle = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"{args.nodes} nodes x Q={args.q}, {args.rounds} rounds "
          f"= {args.rounds*args.q} training steps")

    run = FLRunConfig(algorithm="dsgt", q=args.q, topology="ring",
                      n_nodes=args.nodes, batch_per_node=args.batch_per_node,
                      alpha0=args.alpha0, schedule="constant")
    stream = make_fl_token_batches(cfg.vocab_size, args.nodes,
                                   args.batch_per_node, args.seq_len, q=1, seed=0)
    step_batches = ({k: v[0] for k, v in b.items()} for b in stream)

    t0 = time.time()
    result = train_decentralized(
        bundle.loss_fn, bundle.init_fn(jax.random.key(0)), run,
        step_batches, rounds=args.rounds, log_every=2,
    )
    dt = time.time() - t0
    rows = result.history.rows()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/train_100m_metrics.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=sorted(rows[0]))
        w.writeheader()
        w.writerows(rows)
    save_fl_state(args.ckpt, result.state, extra={"arch": cfg.name})
    print(f"\nloss {rows[0]['loss']:.3f} -> {rows[-1]['loss']:.3f} "
          f"({int(rows[-1]['iteration'])} steps, {dt/60:.1f} min, "
          f"{dt/max(1,int(rows[-1]['iteration'])):.1f}s/step)")
    print(f"metrics -> experiments/train_100m_metrics.csv; ckpt -> {args.ckpt}")


if __name__ == "__main__":
    main()
