"""Quickstart: decentralized federated training in ~40 lines.

Trains a reduced llama-family model across 8 simulated FL nodes on a ring
graph with FD-DSGT (the paper's Algorithm 1), then serves the consensus
model. Runs on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import FLRunConfig, get_config
from repro.data.tokens import make_fl_token_batches
from repro.models import build_model
from repro.serving.engine import ServeEngine
from repro.training.trainer import train_decentralized

# 1. pick an architecture (any of the 10 assigned ids works)
cfg = get_config("tinyllama-1.1b", smoke=True)
bundle = build_model(cfg)

# 2. decentralized FL run config: 8 hospitals on a ring, Q=4 local steps
run = FLRunConfig(algorithm="dsgt", q=4, topology="ring", n_nodes=8,
                  batch_per_node=2, alpha0=0.5, schedule="constant")

# 3. per-node non-IID token streams
stream = make_fl_token_batches(cfg.vocab_size, run.n_nodes, run.batch_per_node,
                               seq_len=64, q=1, seed=0)
step_batches = ({k: v[0] for k, v in b.items()} for b in stream)

# 4. train: Q local steps per node, then one ring-gossip round
result = train_decentralized(
    bundle.loss_fn, bundle.init_fn(jax.random.key(0)), run,
    step_batches, rounds=25, log_every=5,
)
h = result.history
print(f"\nloss {h.rows()[0]['loss']:.3f} -> {h.last()['loss']:.3f} "
      f"in {int(h.last()['comm_rounds'])} comm rounds "
      f"({int(h.last()['iteration'])} iterations)")
print(f"consensus error: {h.last()['consensus_err']:.2e}")

# 5. serve the consensus model
engine = ServeEngine(bundle, result.consensus, max_seq=96, batch=2)
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
out = engine.generate(prompts, max_new_tokens=8, temperature=0.0)
print("generated:", out.tokens[:, 8:].tolist())
