"""Batched serving example: prefill + step-decode across architectures,
including the SSM (RWKV-6) whose decode state is O(1) in context length and
the sliding-window mode used for long_500k decoding.

  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServeEngine


def demo(arch: str, sliding: bool = False, batch: int = 2, max_new: int = 12) -> None:
    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_fn(jax.random.key(0))
    engine = ServeEngine(bundle, params, max_seq=64, batch=batch,
                         sliding_override=sliding)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (batch, 8)).astype(np.int32)
    frames = None
    if cfg.family == "audio":
        frames = rng.normal(size=(batch, cfg.encoder.seq_len, cfg.encoder.d_model)).astype(np.float32)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=max_new, temperature=0.8,
                          seed=1, frames=frames)
    dt = time.time() - t0
    mode = " (sliding-window cache)" if sliding else ""
    print(f"{arch:24s}{mode}: {batch}x{max_new} tokens in {dt:5.1f}s "
          f"-> {out.tokens[0, 8:14].tolist()}...")


if __name__ == "__main__":
    print("batched decode across model families (reduced configs, CPU):")
    demo("tinyllama-1.1b")                 # dense GQA, contiguous KV cache
    demo("qwen2.5-32b", sliding=True)      # dense, ring-buffer window cache
    demo("rwkv6-7b")                       # SSM: O(1) decode state
    demo("recurrentgemma-2b")              # hybrid RG-LRU + local attention
    demo("dbrx-132b")                      # MoE routing per decoded token
    demo("whisper-medium")                 # enc-dec with cross-attention
