"""Serve the consensus WHILE it trains: end-to-end snapshot pipeline.

Two threads over one snapshot directory:

* **trainer** -- decentralized FL (smollm-360m smoke by default, 4-node
  ring, fused flat-buffer engine) advancing the round frontier; every
  ``--publish-every`` rounds it publishes the consensus (one mean over
  the node axis of the flat ``(nodes, total)`` state buffer) as an
  mmap-able snapshot (``repro.training.snapshot.write_snapshot``);

* **server** (main thread) -- waits for the first snapshot, mmap-loads
  it zero-copy into a :class:`~repro.serving.engine.ServeEngine`, then
  replays a deterministic request stream
  (``benchmarks.serve_load.replay``). Between requests it polls
  ``LATEST`` and hot-swaps fresher consensus weights in at decode step
  boundaries -- in-flight batches are never drained, and each request
  reports how many rounds its weights lag the live training frontier
  (the staleness series).

  PYTHONPATH=src python examples/serve_consensus.py
  PYTHONPATH=src python examples/serve_consensus.py --rounds 12 \
      --publish-every 2 --requests 8
"""

import argparse
import json
import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

from benchmarks.serve_load import make_requests, replay  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core import (  # noqa: E402
    FLConfig,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.core.schedules import inv_sqrt  # noqa: E402
from repro.data.tokens import make_fl_token_batches  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402
from repro.training.snapshot import (  # noqa: E402
    latest_round,
    load_snapshot,
    write_snapshot,
)
from repro.training.trainer import stack_for_nodes  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--alpha0", type=float, default=0.02)
    ap.add_argument("--scale-chunk", type=int, default=512)
    ap.add_argument("--publish-every", type=int, default=2,
                    help="rounds between snapshot publishes")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--serve-batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--snap-dir", default=None,
                    help="snapshot directory (default: a temp dir)")
    ap.add_argument("--out", default="experiments/serve_consensus_metrics.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params0 = bundle.init_fn(jax.random.key(0))
    n = args.nodes
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.2f}M params), "
          f"{n}-node ring x Q={args.q}, {args.rounds} rounds, "
          f"publish every {args.publish_every}")

    # ---- build the decentralized round (fused flat-buffer engine)
    w = mixing_matrix("ring", n)
    stacked = stack_for_nodes(params0, n)
    engine, state0 = get_engine("fused").simulated(
        w, stacked, scale_chunk=args.scale_chunk, impl="jnp")
    fl_cfg = FLConfig(algorithm="dsgt", q=args.q, n_nodes=n)
    round_fn = jax.jit(
        make_fl_round(bundle.loss_fn, None, inv_sqrt(args.alpha0), fl_cfg,
                      engine=engine))
    state = init_fl_state(fl_cfg, state0, engine=engine)
    stream = make_fl_token_batches(cfg.vocab_size, n, args.batch_per_node,
                                   args.seq_len, q=args.q, seed=0)

    snap_dir = args.snap_dir or tempfile.mkdtemp(prefix="serve_consensus_")
    frontier = {"round": 0}
    trainer_err = []

    def trainer():
        nonlocal state
        try:
            for rnd in range(1, args.rounds + 1):
                state, m = round_fn(state, next(stream))
                jax.block_until_ready(state.params)
                frontier["round"] = rnd
                if rnd % args.publish_every == 0 or rnd == args.rounds:
                    # state.params IS the flat (nodes, total) buffer;
                    # write_snapshot takes the node-mean = the consensus
                    write_snapshot(snap_dir, state.params, engine.layout,
                                   round_frontier=rnd, engine=engine,
                                   step=int(state.step))
                    print(f"  [trainer] round {rnd}: loss="
                          f"{float(m['loss']):.3f}, published snapshot")
        except Exception as e:  # surface into the main thread
            trainer_err.append(e)
            raise

    th = threading.Thread(target=trainer, daemon=True)
    th.start()

    # ---- serving side: wait for the first publish, then replay
    while latest_round(snap_dir) is None:
        if trainer_err:
            raise trainer_err[0]
        time.sleep(0.05)
    tmpl = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params0)
    snap = load_snapshot(snap_dir, template=tmpl)
    eng = ServeEngine.from_snapshot(
        bundle, snap, max_seq=args.prompt_len + args.new_tokens + 8,
        batch=args.serve_batch)
    print(f"  [server] serving from snapshot round {eng.snapshot_round} "
          f"(mmap {snap.header['blob_bytes']/1e6:.1f} MB zero-copy)")

    def refresh():
        newest = latest_round(snap_dir)
        if newest is not None and newest != eng.snapshot_round:
            eng.publish_snapshot(load_snapshot(snap_dir, newest,
                                               template=tmpl))

    requests = make_requests(args.requests, args.serve_batch,
                             args.prompt_len, cfg.vocab_size, seed=1)
    eng.generate(requests[0], max_new_tokens=2, temperature=0.0)  # warm jit
    row = replay(eng, requests, args.new_tokens,
                 frontier_fn=lambda: frontier["round"], refresh_fn=refresh)
    th.join()
    if trainer_err:
        raise trainer_err[0]

    row.update({"name": f"serve_consensus__{cfg.name}",
                "total_params": int(cfg.param_count()), "n_nodes": n,
                "q": args.q, "rounds": args.rounds,
                "publish_every": args.publish_every,
                "final_round_served": int(eng.snapshot_round)})
    print(f"\nserved {row['gen_tokens']} tokens at "
          f"{row['tokens_per_s']:.1f} tok/s; p50="
          f"{row['us_p50_request']/1e3:.1f}ms p99="
          f"{row['us_p99_request']/1e3:.1f}ms; {row['n_swaps']} hot swaps "
          f"(mean pause {row['us_swap_pause_mean']:.1f}us); staleness "
          f"mean={row.get('staleness_mean', 0):.1f} "
          f"max={row.get('staleness_max', 0)} rounds behind frontier "
          f"{frontier['round']}")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(row, f, indent=2)
    print(f"metrics -> {args.out}; snapshots -> {snap_dir}")


if __name__ == "__main__":
    main()
