"""The paper's experiment end-to-end (Section 3 / Fig. 2) + the fused engine.

Part 1 -- the reproduction: 20 hospitals, ~500 EHR records each (2,103 AD /
7,919 MCI, 42 features), shallow NN per node, hospital communication graph,
m=20, alpha = 0.02/sqrt(r). Compares DSGD, DSGT, FD-DSGD(Q=100),
FD-DSGT(Q=100) and writes the loss-vs-communication-round curves to
experiments/ehr_curves.csv.

Part 2 -- the communication-savings story on the production engine: the
same cohort trained with FD-DSGT on a **GossipEngine from the registry**
(``--fl-engine``, same names as ``launch/dryrun.py`` -- the registry in
``repro.core.engine`` is the single source of truth, so the lists cannot
drift). With the default ``fused`` engine the state lives in one packed
``(nodes, total)`` buffer and every comm round is ONE round-megakernel
call (local update + int8 quantize + W mix + error feedback; see
docs/ARCHITECTURE.md); ``--topk`` sparsifies the wire below int8. Prints
per-round comm bytes of the difference-coded wire vs the fp32 wire the
plain engine ships, i.e. the paper's round savings (Q local steps per
exchange) COMPOSED with the engine's byte savings.

  PYTHONPATH=src python examples/ehr_federated.py [--iterations 3000]
  PYTHONPATH=src python examples/ehr_federated.py --iterations 300 \
      --fused-rounds 50 --fl-engine fused --topk 64
"""

import argparse
import csv
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

# the fig2 driver lives in benchmarks/, next to this examples/ directory
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fig2_comm_rounds import ALGOS, comm_rounds_to_loss, run  # noqa: E402
from repro.core import (
    FLConfig,
    engine_names,
    get_engine,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
)
from repro.configs.ehr_mlp import CLASS_WEIGHT, class_weights, topk_schedule
from repro.core.dynamics import program_names
from repro.core.engine import schedule_names
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import (
    make_mlp_loss,
    mlp_accuracy,
    mlp_balanced_accuracy,
    mlp_init,
)
from repro.training.trainer import AdaptiveTopK, stack_for_nodes


def run_fused_engine(rounds: int, q: int, scale_chunk: int = 512, seed: int = 0,
                     fl_engine: str = "fused", topk=None,
                     class_weight=CLASS_WEIGHT, fl_schedule="sequential",
                     topk_schedule=None, topology_program=None,
                     privacy=None, scope=None):
    """FD-DSGT on a registry engine: one megakernel call per comm round
    on the default ``fused`` engine, with the class-weighted loss
    (``configs.ehr_mlp.class_weights``) unless ``class_weight=None`` --
    part 1 stays paper-faithful unweighted.

    ``fl_schedule="pipelined"`` runs the overlapped round schedule
    (collective in flight across the Q local steps, one-round-stale
    mixing); ``topk_schedule=(k_sparse, k_dense, high[, low])`` runs the
    adaptive-k wire -- sparse k until the EF-residual RMS crosses the
    high threshold, then dense until it drains below the low one (the
    hysteresis band); ``topology_program`` (a registry spec like
    "node_churn:p_down=0.2,mean_downtime=5") makes the hospital graph
    TIME-VARYING -- per-round link/node outages with dropped weight
    folded into the self-loops, inside the one compiled round;
    ``privacy`` (a spec like "secure_agg+dp:sigma=0.5,clip=1.0") adds
    the wire's privacy epilogue -- the hospitals' whole reason for
    gossiping instead of pooling records -- with the per-round
    ``dp_epsilon`` moments bound reported alongside the loss;
    ``scope`` (a spec like "backbone") restricts gossip to the shared
    backbone columns -- each hospital's classifier head stays private
    (bit-untouched by the wire) and the wire shrinks to the shared
    slice."""
    if rounds < 1:
        raise ValueError("--fused-rounds must be >= 1")
    if topk_schedule is not None and topk is not None:
        raise ValueError("pass either --topk or --topk-schedule, not both")
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)

    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    adaptive = (AdaptiveTopK(topk_schedule, scale_chunk)
                if topk_schedule is not None else None)
    if adaptive is not None:
        topk = adaptive.k_sparse
    engine, state0 = get_engine(fl_engine).simulated(
        w, params, scale_chunk=scale_chunk, topk=topk, impl="pallas",
        round_schedule=fl_schedule, topology_program=topology_program,
        privacy=privacy, scope=scope,
    )
    loss_fn = make_mlp_loss(class_weights(class_weight))
    round_fn = jax.jit(
        make_fl_round(loss_fn, None, inv_sqrt(0.02), cfg, engine=engine)
    )
    dense_fn = None
    if adaptive is not None:
        # the densified twin advances the SAME state (comm keys are
        # k-independent); both jitted once, switched per round by the
        # shared AdaptiveTopK controller on the ef_residual_rms metric
        dense_engine, _ = get_engine(fl_engine).simulated(
            w, params, scale_chunk=scale_chunk, topk=adaptive.dense_topk,
            impl="pallas", round_schedule=fl_schedule,
            topology_program=topology_program, privacy=privacy,
            scope=scope,
        )
        dense_fn = jax.jit(
            make_fl_round(loss_fn, None, inv_sqrt(0.02), cfg,
                          engine=dense_engine)
        )
    state = init_fl_state(cfg, state0, engine=engine)

    # Wire accounting: the fused engines ship int8 (or top-k sparsified)
    # payloads + one fp32 scale per (node, scale_chunk) block (padding
    # included -- it travels) and report it in the wire_bytes metric; the
    # exact-wire engines (tree/flat) ship the unpadded pytree in fp32.
    # DSGT ships params AND tracker on both.
    n_params = sum(
        int(np.prod(l.shape[1:])) for l in jax.tree_util.tree_leaves(params)
    )
    degrees = (w - np.diag(np.diag(w)) > 0).sum(axis=1)
    fp32_bytes = float(2 * degrees.sum() * n_params * 4)
    engine_bytes = engine.wire_bytes(cfg)  # None: engine ships the fp32 wire
    layout_note = (
        f"{n_params} params -> {engine.layout.total} padded, "
        f"chunk={scale_chunk}, topk={topk}"
        if engine.layout is not None else f"{n_params} params, exact fp32 wire"
    )
    wire_label = (
        "fp32" if engine_bytes is None else f"top-{topk}" if topk else "int8"
    )

    graph_note = (f"hospital graph x {engine.topology_program.spec()}"
                  if engine.dynamic_topology else "hospital graph")
    priv_note = (f", privacy={engine.privacy.spec()}"
                 if engine.privacy.active else "")
    scope_note = ""
    if not engine.scope.is_full:
        wire_layout = getattr(engine, "wire_layout", engine.layout)
        scope_note = (f", scope={engine.scope.spec()} "
                      f"({wire_layout.total}/{engine.layout.total} wire cols)")
    print(f"\n{fl_engine} engine (FD-DSGT, Q={q}, schedule={fl_schedule}, "
          f"{graph_note}, class_weight={class_weight}{priv_note}"
          f"{scope_note}, {layout_note}):")
    m = None
    for rnd in range(1, rounds + 1):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        fn = adaptive.pick(round_fn, dense_fn) if adaptive else round_fn
        state, m = fn(state, batches)
        if rnd % max(1, rounds // 5) == 0 or rnd == 1:
            per_round = float(m.get("wire_bytes", fp32_bytes))
            k_note = (f" k={adaptive.current_k} "
                      f"resid={float(m['ef_residual_rms']):.1e}"
                      if adaptive is not None else "")
            churn_note = (f" edges_up={float(m['edge_fraction']):.0%}"
                          if "edge_fraction" in m else "")
            churn_note += (f" eps={float(m['dp_epsilon']):.2f}"
                           if "dp_epsilon" in m else "")
            print(f"  [round {rnd:4d}] loss={float(m['loss']):.4f} "
                  f"consensus_err={float(m['consensus_err']):.2e} "
                  f"comm_bytes/round={per_round:,.0f} ({wire_label} wire) "
                  f"vs {fp32_bytes:,.0f} (fp32 wire){k_note}{churn_note}")
        if adaptive is not None:
            adaptive.update(float(m["ef_residual_rms"]))
    if adaptive is not None:
        print(f"  adaptive k: {adaptive.dense_rounds}/{rounds} rounds "
              f"densified to k={adaptive.k_dense} (EF residual RMS > "
              f"{adaptive.threshold:g}), "
              f"{rounds - adaptive.dense_rounds} stayed at "
              f"k={adaptive.k_sparse}")

    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), engine.params_view(state.params)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    acc = float(mlp_accuracy(consensus, xall, yall))
    bal = float(mlp_balanced_accuracy(consensus, xall, yall))
    wire_bytes = float(m.get("wire_bytes", fp32_bytes))
    saving = fp32_bytes / wire_bytes
    print(f"  final acc={acc:.3f} bal_acc={bal:.3f}  "
          f"wire saving: {saving:.2f}x "
          f"bytes/round on top of the {q}x round saving (Q={q} local steps "
          f"per exchange) => {q * saving:.0f}x fewer bytes "
          f"per iteration than comm-every-step fp32 gossip")
    return {"acc": acc, "bal_acc": bal, "wire_saving": saving,
            "dense_rounds": adaptive.dense_rounds if adaptive else None,
            "dp_epsilon": float(m["dp_epsilon"]) if m is not None
            and "dp_epsilon" in m else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--out", default="experiments/ehr_curves.csv")
    ap.add_argument("--fused-rounds", type=int, default=50,
                    help="comm rounds for the fused-engine demo (part 2)")
    ap.add_argument("--fused-q", type=int, default=10,
                    help="local steps per comm round for the fused demo")
    # same registry as launch/dryrun.py; mesh-only engines are excluded
    # up front (this is a single-host driver) instead of crashing after
    # the expensive part-1 run
    ap.add_argument("--fl-engine", default="fused",
                    choices=[n for n in engine_names()
                             if not get_engine(n).needs_mesh],
                    help="registry engine for part 2 (same names as "
                         "launch/dryrun.py --fl-engine; mesh-only engines "
                         "need launch/dryrun.py)")
    ap.add_argument("--topk", type=int, default=None,
                    help="fused engines: k payload columns per scale chunk")
    ap.add_argument("--fl-schedule", default="sequential",
                    choices=schedule_names(),
                    help="round time layout for part 2: pipelined overlaps "
                         "the collective with the next round's local steps "
                         "(one-round-stale mixing)")
    ap.add_argument("--topk-schedule", default=None,
                    help="adaptive k as 'k_sparse:k_dense:high[:low]' or "
                         "'config' for configs.ehr_mlp.TOPK_SCHEDULE -- "
                         "densifies the wire when the EF-residual RMS "
                         "exceeds the high threshold, re-sparsifies only "
                         "below the low one (hysteresis)")
    ap.add_argument("--fl-topology-program", default=None,
                    help="per-round graph dynamics for part 2 "
                         f"(TopologyProgram registry: "
                         f"{', '.join(program_names())}); e.g. "
                         "'node_churn:p_down=0.2,mean_downtime=5' makes "
                         "the hospital graph time-varying")
    ap.add_argument("--fl-privacy", default=None,
                    help="wire privacy epilogue for part 2 (PrivacySpec): "
                         "'secure_agg' masks every neighbor payload "
                         "(cancels exactly under the mix -- bit-identical "
                         "training), 'dp:sigma=0.5,clip=1.0' adds clipped "
                         "Gaussian noise with the dp_epsilon moments "
                         "bound reported per round, or both with '+'")
    ap.add_argument("--fl-scope", default=None,
                    help="federation scope for part 2 (FederationScope "
                         "registry): 'backbone' shares everything but "
                         "the classifier head (per-hospital heads stay "
                         "private, wire shrinks to the shared slice), "
                         "'ranges:a-b,...' picks explicit columns, "
                         "'layerwise:freq=R' gossips the head every R "
                         "rounds (fused engine)")
    ap.add_argument("--scale-chunk", type=int, default=512,
                    help="part-2 quantization chunk; the scoped wire "
                         "pads to a chunk multiple, so pair --fl-scope "
                         "backbone with a chunk <= 128 to see the wire "
                         "bytes actually shrink on the 1442-param MLP")
    ap.add_argument("--class-weight", default=CLASS_WEIGHT,
                    help="part-2 loss weighting: 'balanced' (inverse "
                         "frequency, lifts balanced accuracy off the ~0.6 "
                         "saturation) or 'none' for the paper-faithful "
                         "unweighted loss")
    args = ap.parse_args()

    results = run(iterations=args.iterations)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algorithm", "comm_round", "loss", "grad_norm_sq", "consensus_err"])
        for name, r in results.items():
            for i in range(len(r["comm_rounds"])):
                w.writerow([name, int(r["comm_rounds"][i]), r["loss"][i],
                            r["grad_norm_sq"][i], r["consensus_err"][i]])
    print(f"\ncurves -> {args.out}")

    target = 1.10 * max(results["DSGT"]["final_loss"], results["DSGD"]["final_loss"])
    to_t = comm_rounds_to_loss(results, target)
    print(f"comm rounds to loss<={target:.4f}:")
    for k, v in to_t.items():
        print(f"  {k:18s} {v:8.0f}")

    if args.topk_schedule is None:
        tks = None
    elif args.topk_schedule == "config":
        tks = topk_schedule()
    else:
        tks = topk_schedule(tuple(args.topk_schedule.split(":")))

    part2 = run_fused_engine(rounds=args.fused_rounds, q=args.fused_q,
                             scale_chunk=args.scale_chunk,
                             fl_engine=args.fl_engine, topk=args.topk,
                             class_weight=None if args.class_weight == "none"
                             else args.class_weight,
                             fl_schedule=args.fl_schedule,
                             topk_schedule=tks,
                             topology_program=args.fl_topology_program,
                             privacy=args.fl_privacy,
                             scope=args.fl_scope)

    print("\nPaper claims validated:")
    print("  * FD variants converge with ~2 orders of magnitude fewer comm rounds")
    print("  * all four algorithms reach comparable loss at the same iteration budget")
    if part2["wire_saving"] > 1.0:
        print(f"  * the {args.fl_engine} engine shipped the same rounds in "
              f"{part2['wire_saving']:.1f}x fewer bytes than the fp32 wire")
    else:
        print(f"  * the {args.fl_engine} engine ships the exact fp32 wire "
              "(use fused engines +/- --topk for the byte savings)")


if __name__ == "__main__":
    main()
