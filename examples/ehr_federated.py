"""The paper's experiment end-to-end (Section 3 / Fig. 2).

20 hospitals, ~500 EHR records each (2,103 AD / 7,919 MCI, 42 features),
shallow NN per node, hospital communication graph, m=20, alpha = 0.02/sqrt(r).
Compares DSGD, DSGT, FD-DSGD(Q=100), FD-DSGT(Q=100) and writes the
loss-vs-communication-round curves to experiments/ehr_curves.csv.

  PYTHONPATH=src python examples/ehr_federated.py [--iterations 3000]
"""

import argparse
import csv
import os

from benchmarks.fig2_comm_rounds import ALGOS, comm_rounds_to_loss, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--out", default="experiments/ehr_curves.csv")
    args = ap.parse_args()

    results = run(iterations=args.iterations)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algorithm", "comm_round", "loss", "grad_norm_sq", "consensus_err"])
        for name, r in results.items():
            for i in range(len(r["comm_rounds"])):
                w.writerow([name, int(r["comm_rounds"][i]), r["loss"][i],
                            r["grad_norm_sq"][i], r["consensus_err"][i]])
    print(f"\ncurves -> {args.out}")

    target = 1.10 * max(results["DSGT"]["final_loss"], results["DSGD"]["final_loss"])
    to_t = comm_rounds_to_loss(results, target)
    print(f"comm rounds to loss<={target:.4f}:")
    for k, v in to_t.items():
        print(f"  {k:18s} {v:8.0f}")
    print("\nPaper claims validated:")
    print("  * FD variants converge with ~2 orders of magnitude fewer comm rounds")
    print("  * all four algorithms reach comparable loss at the same iteration budget")


if __name__ == "__main__":
    main()
