"""The paper's experiment end-to-end (Section 3 / Fig. 2) + the fused engine.

Part 1 -- the reproduction: 20 hospitals, ~500 EHR records each (2,103 AD /
7,919 MCI, 42 features), shallow NN per node, hospital communication graph,
m=20, alpha = 0.02/sqrt(r). Compares DSGD, DSGT, FD-DSGD(Q=100),
FD-DSGT(Q=100) and writes the loss-vs-communication-round curves to
experiments/ehr_curves.csv.

Part 2 -- the communication-savings story on the production engine: the
same cohort trained with FD-DSGT on the **flat/fused path**
(``make_fl_round(layout=..., fused=...)``): the state lives in one packed
``(nodes, total)`` buffer and every comm round is ONE round-megakernel
call (local update + int8 quantize + W mix + error feedback; see
docs/ARCHITECTURE.md). Prints per-round comm bytes of the int8
difference-coded wire vs the fp32 wire the plain engine ships, i.e. the
paper's round savings (Q local steps per exchange) COMPOSED with the
engine's byte savings (int8 wire).

  PYTHONPATH=src python examples/ehr_federated.py [--iterations 3000]
  PYTHONPATH=src python examples/ehr_federated.py --iterations 300 --fused-rounds 50
"""

import argparse
import csv
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

# the fig2 driver lives in benchmarks/, next to this examples/ directory
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fig2_comm_rounds import ALGOS, comm_rounds_to_loss, run  # noqa: E402
from repro.core import (
    FLConfig,
    FusedRoundSpec,
    init_fl_state,
    make_fl_round,
    mixing_matrix,
    pack,
    unpack,
)
from repro.core.schedules import inv_sqrt
from repro.data.ehr import generate_ehr_cohort, make_node_batcher
from repro.models.mlp import mlp_accuracy, mlp_init, mlp_loss
from repro.training.trainer import stack_for_nodes


def run_fused_engine(rounds: int, q: int, scale_chunk: int = 512, seed: int = 0):
    """FD-DSGT on the flat/fused engine: one megakernel call per comm round."""
    if rounds < 1:
        raise ValueError("--fused-rounds must be >= 1")
    n = 20
    data = generate_ehr_cohort(seed=seed)
    w = mixing_matrix("hospital20", n)
    batcher = make_node_batcher(data, m=20, seed=seed + 1)

    params = stack_for_nodes(mlp_init(jax.random.key(seed)), n)
    flat, layout = pack(params, pad_to=scale_chunk)
    cfg = FLConfig(algorithm="dsgt", q=q, n_nodes=n)
    spec = FusedRoundSpec(w=w, scale_chunk=scale_chunk, impl="pallas")
    round_fn = jax.jit(
        make_fl_round(mlp_loss, None, inv_sqrt(0.02), cfg, layout=layout, fused=spec)
    )
    state = init_fl_state(cfg, flat, fused=True)

    # Wire accounting: the fused engine ships int8 payloads + one fp32
    # scale per (node, scale_chunk) block (padding included -- it travels);
    # the plain engine ships the unpadded pytree in fp32. DSGT ships
    # params AND tracker on both.
    degrees = (w - np.diag(np.diag(w)) > 0).sum(axis=1)
    fp32_bytes = float(2 * degrees.sum() * layout.used * 4)

    print(f"\nFused flat engine (FD-DSGT, Q={q}, hospital graph, "
          f"{layout.used} params -> {layout.total} padded, chunk={scale_chunk}):")
    m = None
    for rnd in range(1, rounds + 1):
        qs = [next(batcher) for _ in range(q)]
        batches = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *qs)
        state, m = round_fn(state, batches)
        if rnd % max(1, rounds // 5) == 0 or rnd == 1:
            print(f"  [round {rnd:4d}] loss={float(m['loss']):.4f} "
                  f"consensus_err={float(m['consensus_err']):.2e} "
                  f"comm_bytes/round={float(m['wire_bytes']):,.0f} (int8 fused) "
                  f"vs {fp32_bytes:,.0f} (fp32 wire)")

    consensus = jax.tree_util.tree_map(
        lambda p: jnp.mean(p, axis=0), unpack(state.params, layout)
    )
    xall = jnp.asarray(np.concatenate(data.features))
    yall = jnp.asarray(np.concatenate(data.labels))
    acc = float(mlp_accuracy(consensus, xall, yall))
    int8_bytes = float(m["wire_bytes"])
    print(f"  final acc={acc:.3f}  wire saving: {fp32_bytes / int8_bytes:.2f}x "
          f"bytes/round on top of the {q}x round saving (Q={q} local steps "
          f"per exchange) => {q * fp32_bytes / int8_bytes:.0f}x fewer bytes "
          f"per iteration than comm-every-step fp32 gossip")
    return acc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--out", default="experiments/ehr_curves.csv")
    ap.add_argument("--fused-rounds", type=int, default=50,
                    help="comm rounds for the fused-engine demo (part 2)")
    ap.add_argument("--fused-q", type=int, default=10,
                    help="local steps per comm round for the fused demo")
    args = ap.parse_args()

    results = run(iterations=args.iterations)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["algorithm", "comm_round", "loss", "grad_norm_sq", "consensus_err"])
        for name, r in results.items():
            for i in range(len(r["comm_rounds"])):
                w.writerow([name, int(r["comm_rounds"][i]), r["loss"][i],
                            r["grad_norm_sq"][i], r["consensus_err"][i]])
    print(f"\ncurves -> {args.out}")

    target = 1.10 * max(results["DSGT"]["final_loss"], results["DSGD"]["final_loss"])
    to_t = comm_rounds_to_loss(results, target)
    print(f"comm rounds to loss<={target:.4f}:")
    for k, v in to_t.items():
        print(f"  {k:18s} {v:8.0f}")

    run_fused_engine(rounds=args.fused_rounds, q=args.fused_q)

    print("\nPaper claims validated:")
    print("  * FD variants converge with ~2 orders of magnitude fewer comm rounds")
    print("  * all four algorithms reach comparable loss at the same iteration budget")
    print("  * the fused engine ships the same rounds in ~3.7x fewer bytes (int8 wire)")


if __name__ == "__main__":
    main()
