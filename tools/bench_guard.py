"""Bench-regression guard: diff a fresh gossip-bench JSON against the
committed baseline and FAIL on real regressions.

    python tools/bench_guard.py --baseline benchmarks/BENCH_gossip_smoke.json \
        --fresh BENCH_gossip_smoke_fresh.json [--wire-tol 0.25] [--latency-tol 0.25]

What is guarded, and why only that:

* **Wire bytes** (every ``*wire_bytes*`` / ``*_bytes*`` field): these are
  deterministic functions of the encoding (``packing.flat_wire_bytes`` ==
  the collective operand sizes), so ANY growth beyond ``--wire-tol``
  (default 25%) is a genuine wire regression, not noise.
* **Latency ratios** (``speedup_*`` / ``*_reduction*`` fields):
  absolute microseconds on a shared CI runner swing far more than any
  real code change, but the bench times its variants INTERLEAVED, so the
  RATIOS are noise-robust; a ratio dropping below
  ``baseline * (1 - latency_tol)`` means the optimized path lost ground
  against its own baseline on the same box. MODELED columns
  (``overlap_model_*``) are differences of small timings -- they amplify
  noise and are reported for reading, never gated (see
  ``_is_ratio_field``). Absolute ``us_*`` columns are likewise ungated.

Rows are matched by ``name`` and compared only when their shape knobs
(n_nodes / total_params) agree -- a smoke-shape fresh run silently skips
rows against a full-shape baseline rather than comparing apples to
oranges (keep a smoke baseline committed for the smoke CI job).

Exit code 1 on any regression; prints a table either way.
"""

from __future__ import annotations

import argparse
import json
import sys

SHAPE_KEYS = ("n_nodes", "total_params", "n_leaves", "scale_chunk", "topk",
              "q", "degree", "model_shards")


def _is_wire_field(key: str) -> bool:
    return "bytes" in key and isinstance(key, str)


def _is_ratio_field(key: str) -> bool:
    # Directly MEASURED ratios only. Modeled columns (overlap_model_*)
    # are differences of small timings -- noise-amplifying -- and are
    # reported for reading, not gated.
    return key.startswith("speedup_") or "_reduction" in key


def compare(baseline: dict, fresh: dict, wire_tol: float,
            latency_tol: float) -> list:
    base_rows = {r["name"]: r for r in baseline["rows"]}
    failures = []
    checked = 0
    for row in fresh["rows"]:
        base = base_rows.get(row["name"])
        if base is None:
            print(f"  [new row]   {row['name']} (no baseline -- skipped)")
            continue
        mismatched = [k for k in SHAPE_KEYS
                      if base.get(k) != row.get(k)]
        if mismatched:
            print(f"  [skip]      {row['name']}: shape knobs differ "
                  f"({', '.join(mismatched)}) -- baseline is a different "
                  "configuration")
            continue
        for key, fresh_v in row.items():
            base_v = base.get(key)
            if not isinstance(fresh_v, (int, float)) or \
                    not isinstance(base_v, (int, float)):
                continue
            if key in SHAPE_KEYS:
                continue
            if _is_wire_field(key):
                limit = base_v * (1.0 + wire_tol)
                ok = fresh_v <= limit
                kind = f"wire  (<= {limit:.0f})"
            elif _is_ratio_field(key):
                limit = base_v * (1.0 - latency_tol)
                ok = fresh_v >= limit
                kind = f"ratio (>= {limit:.2f})"
            else:
                continue  # absolute latencies: too noisy on shared runners
            checked += 1
            status = "ok " if ok else "REGRESSION"
            print(f"  [{status}] {row['name']}.{key}: "
                  f"baseline={base_v:.4g} fresh={fresh_v:.4g} {kind}")
            if not ok:
                failures.append((row["name"], key, base_v, fresh_v))
    if checked == 0:
        print("  WARNING: no comparable fields found -- baseline and fresh "
              "runs share no matching rows/shapes")
        failures.append(("<none>", "no_comparable_fields", 0, 0))
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--wire-tol", type=float, default=0.25,
                    help="max tolerated wire-byte growth (fraction)")
    ap.add_argument("--latency-tol", type=float, default=0.25,
                    help="max tolerated drop of a speedup/reduction ratio "
                         "(fraction); raise for tiny smoke shapes")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    print(f"bench guard: {args.fresh} vs baseline {args.baseline} "
          f"(wire tol {args.wire_tol:.0%}, latency-ratio tol "
          f"{args.latency_tol:.0%})")
    failures = compare(baseline, fresh, args.wire_tol, args.latency_tol)
    if failures:
        print(f"\n{len(failures)} regression(s):")
        for name, key, b, f_ in failures:
            print(f"  {name}.{key}: {b:.4g} -> {f_:.4g}")
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
