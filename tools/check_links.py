#!/usr/bin/env python
"""Fail CI on broken intra-repo markdown links.

Scans README.md, ROADMAP.md, CHANGES.md, and docs/*.md for inline
markdown links ``[text](target)`` and checks that every RELATIVE target
(anything that is not http(s)/mailto or a pure #anchor) resolves to an
existing file or directory, after stripping any #fragment. External URLs
are deliberately not fetched -- this guards the repo's internal
documentation graph, not the internet.

Usage: python tools/check_links.py  (exit 1 + report on broken links)
"""

from __future__ import annotations

import glob
import os
import re
import sys

# inline links, skipping images' leading ! is harmless (path must exist
# either way); excludes autolinks <...> and reference-style definitions
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

_EXTERNAL = ("http://", "https://", "mailto:")


def iter_md_files(repo_root: str):
    for name in ("README.md", "ROADMAP.md", "CHANGES.md"):
        path = os.path.join(repo_root, name)
        if os.path.exists(path):
            yield path
    yield from sorted(glob.glob(os.path.join(repo_root, "docs", "*.md")))


def broken_links(md_path: str):
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    for match in _LINK_RE.finditer(text):
        target = match.group(1)
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            line = text.count("\n", 0, match.start()) + 1
            yield line, target


def main() -> int:
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = []
    checked = 0
    for md in iter_md_files(repo_root):
        checked += 1
        for line, target in broken_links(md):
            failures.append(f"{os.path.relpath(md, repo_root)}:{line}: broken link -> {target}")
    if failures:
        print("\n".join(failures))
        print(f"\n{len(failures)} broken intra-repo link(s).")
        return 1
    print(f"checked {checked} markdown file(s): all intra-repo links resolve.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
